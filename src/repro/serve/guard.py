"""Serving-engine fault tolerance: health state machine + poison sentinels.

A serving engine sharing one batched launch across many requests has a
blast-radius problem: one poisoned slot — a NaN that crept into its logits,
a corrupt byte in its packed KV page — must not take down the other
``n_slots - 1`` requests riding the same jitted step. This module gives
``ServeEngine`` the machinery to contain it:

* **In-jit sentinels** (:func:`probe_logits`, :func:`probe_kv`) — tiny
  per-slot reductions traced into the decode/prefill graphs (same pattern
  as ``repro.obs.quant_health``: reductions inside jit, scalars shipped to
  the host with ``jax.debug.callback``). ``probe_logits`` counts non-finite
  values in each slot's sampled logit row; ``probe_kv`` counts non-finite
  floats and illegal scale bytes (255: E8M0-reserved / e4m3 NaN — legal
  pages hold [0, 254], 0 being the zero-init of empty pages) across each
  slot's cache rows. Counts land in a :class:`SentinelMailbox` the engine
  drains after each launch.

* **A per-engine health state machine** (:class:`EngineGuard`) with three
  states — HEALTHY, DEGRADED (faults observed and contained: quarantines,
  watchdog trips, step retries; service continues), FAILED (fault budget
  exhausted or an unrecoverable error; the engine refuses further steps) —
  plus the fault-budget knobs of :class:`GuardConfig` and the
  ``repro_guard_*`` metrics (gated by ``REPRO_OBS`` like every pillar).

* **Packed-stream verification** (:func:`verify_packed_tree`) — codec
  stream validation over a packed weight tree with graceful degradation:
  re-quantize broken leaves from source weights when available (the
  encoders are deterministic, so an intact leaf re-packs bit-identically),
  else clamp scale bytes back into range (bounded error instead of inf),
  else raise :class:`StreamIntegrityError`.

Blast-radius containment relies on batch-row independence: every launch
computes slot rows independently (pinned by the batched-vs-single parity
tests), so evicting a poisoned slot leaves the survivors' tokens
bit-identical to a fault-free run — which tests/test_faults.py asserts.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import numpy as np

from repro import obs

__all__ = [
    "HEALTHY", "DEGRADED", "FAILED", "HEALTH_LEVEL",
    "TransientStepError", "EngineFailedError", "StreamIntegrityError",
    "GuardConfig", "SentinelMailbox", "EngineGuard",
    "probe_logits", "probe_kv", "verify_packed_tree",
]

HEALTHY, DEGRADED, FAILED = "healthy", "degraded", "failed"
HEALTH_LEVEL = {HEALTHY: 0, DEGRADED: 1, FAILED: 2}

# u8 scale byte that no encoder emits: E8M0 reserved/NaN (decodes to 2^128)
# and the sign bit + NaN mantissa pattern of e4m3. Byte 0 is legal — it is
# the zero-init of empty KV pages.
_POISON_SCALE_BYTE = 255


class TransientStepError(RuntimeError):
    """A launch failed before touching device state (injected fault, host
    hiccup) and is safe to retry: donated buffers were not consumed."""


class EngineFailedError(RuntimeError):
    """The engine's fault budget is exhausted (FAILED state); it refuses
    further steps. Restart from a verified checkpoint."""


class StreamIntegrityError(RuntimeError):
    """Packed weight streams are corrupt and no repair path is available
    (no source weights to re-quantize from, damage beyond scale clamping).
    ``leaves`` maps leaf path -> problem list."""

    def __init__(self, message: str, leaves: Optional[dict] = None):
        super().__init__(message)
        self.leaves = leaves or {}


@dataclasses.dataclass
class GuardConfig:
    """Fault-tolerance knobs for :class:`EngineGuard`.

    nan_checks / kv_checks : trace the logits / KV sentinels into the
        jitted launches. Trace-time gates: with both off the launch graphs
        are byte-identical to an unguarded engine.
    watchdog_s : wall-clock budget per launch; a slower step trips the
        watchdog and degrades the engine (None = no watchdog). Callers must
        warm the jit caches first — compilation easily exceeds any sane
        budget (benchmarks/serve_bench.py --chaos does).
    max_step_retries : retries of a launch that raised
        :class:`TransientStepError` before the engine gives up and FAILs.
    retry_backoff_s : sleep before retry i is ``retry_backoff_s * 2**i``
        (exponential backoff).
    recovery_steps : consecutive clean steps after which a DEGRADED engine
        returns to HEALTHY.
    max_quarantines : quarantine budget; exceeding it FAILs the engine
        (None = unlimited — quarantines degrade but never kill).
    verify_on_admit : probability of running codec stream validation over
        one admitted request's slot-independent weight tree sample (0.0 =
        never; cheap spot check against in-HBM corruption).
    seed : RNG seed for the admit-sampling coin flips (determinism).
    """

    nan_checks: bool = True
    kv_checks: bool = True
    watchdog_s: Optional[float] = None
    max_step_retries: int = 2
    retry_backoff_s: float = 0.05
    recovery_steps: int = 3
    max_quarantines: Optional[int] = None
    verify_on_admit: float = 0.0
    seed: int = 0


class SentinelMailbox:
    """Thread-safe accumulator between ``jax.debug.callback`` (which may
    fire from a runtime thread, asynchronously) and the engine's host loop.
    ``deliver`` adds a per-slot count vector for a site; ``drain`` returns
    and clears {site: summed counts}."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counts: Dict[str, np.ndarray] = {}

    def deliver(self, site: str, counts) -> None:
        c = np.asarray(counts, np.int64).reshape(-1)
        with self._lock:
            prev = self._counts.get(site)
            self._counts[site] = c if prev is None else prev + c

    def drain(self) -> Dict[str, np.ndarray]:
        with self._lock:
            out, self._counts = self._counts, {}
        return out


def probe_logits(mailbox: SentinelMailbox, logits, lengths=None) -> None:
    """Trace a per-slot non-finite count over the logits each slot samples
    from. Call INSIDE jit.

    ``logits``: (B, V) — the row each slot's next token is sampled from.
    ``lengths``: optional (B,) planned chunk lengths; rows planned 0 tokens
    are masked out (an idle prefill row legitimately softmaxes over an
    all-masked attention window and is allowed to be NaN — nothing samples
    from it)."""
    import jax
    import jax.numpy as jnp
    bad = jnp.sum(~jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
    if lengths is not None:
        bad = jnp.where(lengths > 0, bad, 0)
    jax.debug.callback(lambda c: mailbox.deliver("logits", c),
                       bad.astype(jnp.int32))


def probe_kv(mailbox: SentinelMailbox, caches, n_slots: int) -> None:
    """Trace a per-slot poison count over the cache pool. Call INSIDE jit,
    on the post-launch caches.

    Flags, per slot (cache leaves are layer-stacked with the slot axis
    second): non-finite values in float leaves (K/V pages, recurrent
    state — ``pos`` tracks are integers and skipped) and the reserved
    scale byte 255 in packed-KV u8 ``scales`` streams. All leaves sum into
    one (B,) vector delivered to site ``"kv"``."""
    import jax
    import jax.numpy as jnp
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    total = jnp.zeros((n_slots,), jnp.int32)
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", "")) if path else ""
        if leaf.ndim < 2:
            continue
        axes = tuple(a for a in range(leaf.ndim) if a != 1)
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            total = total + jnp.sum(
                ~jnp.isfinite(leaf.astype(jnp.float32)), axis=axes
            ).astype(jnp.int32)
        elif leaf.dtype == jnp.uint8 and name == "scales":
            total = total + jnp.sum(
                leaf == _POISON_SCALE_BYTE, axis=axes).astype(jnp.int32)
    jax.debug.callback(lambda c: mailbox.deliver("kv", c), total)


class EngineGuard:
    """Health state machine + fault accounting for one ``ServeEngine``.

    The engine calls :meth:`drain` after every launch (barriers the
    pending debug callbacks, empties the mailbox), records contained
    faults through the ``record_*`` methods, and :meth:`note_step` at the
    end of each step — which runs the watchdog and the DEGRADED->HEALTHY
    recovery streak. All ``repro_guard_*`` metrics are gated by
    ``REPRO_OBS`` (``obs.enabled()``) like every other pillar."""

    def __init__(self, cfg: Optional[GuardConfig] = None):
        self.cfg = cfg or GuardConfig()
        self.state = HEALTHY
        self.mailbox = SentinelMailbox()
        self.quarantines = 0
        self.scrubs = 0
        self.retries = 0
        self.watchdog_trips = 0
        self.expired = 0
        self.shed = 0
        self.degraded_steps = 0
        self.fail_reason = ""
        self._streak = 0                   # consecutive clean steps
        self._dirty_step = False           # fault recorded this step
        self._rng = np.random.default_rng(self.cfg.seed)
        self._set_state_gauge()

    # -- state machine -----------------------------------------------------

    def _set_state_gauge(self) -> None:
        if obs.enabled():
            obs.gauge("repro_guard_health_state",
                      "engine health (0 healthy, 1 degraded, 2 failed)"
                      ).set(HEALTH_LEVEL[self.state])

    def _escalate(self, to: str) -> None:
        if HEALTH_LEVEL[to] > HEALTH_LEVEL[self.state]:
            self.state = to
            self._set_state_gauge()

    def degrade(self) -> None:
        self._streak = 0
        self._dirty_step = True
        self._escalate(DEGRADED)

    def fail(self, reason: str) -> None:
        self.fail_reason = self.fail_reason or reason
        self._escalate(FAILED)

    def check_alive(self) -> None:
        if self.state == FAILED:
            raise EngineFailedError(
                f"engine is FAILED ({self.fail_reason}); restart from a "
                f"verified checkpoint (load_packed_checkpoint(..., "
                f"verify=True))")

    def note_step(self, dt: float) -> None:
        """End-of-step bookkeeping: watchdog + recovery streak."""
        if self.cfg.watchdog_s is not None and dt > self.cfg.watchdog_s:
            self.watchdog_trips += 1
            if obs.enabled():
                obs.counter("repro_guard_watchdog_trips_total",
                            "launches over the wall-clock budget").inc()
            self.degrade()
        if self.state == DEGRADED:
            self.degraded_steps += 1
            if obs.enabled():
                obs.counter("repro_guard_degraded_steps_total",
                            "steps served while DEGRADED").inc()
            if self._dirty_step:
                self._streak = 0
            else:
                self._streak += 1
                if self._streak >= self.cfg.recovery_steps:
                    self.state = HEALTHY
                    self._streak = 0
                    self._set_state_gauge()
        self._dirty_step = False

    # -- sentinel plumbing ---------------------------------------------------

    def drain(self) -> Dict[str, np.ndarray]:
        """Flush pending debug callbacks and return {site: per-slot poison
        counts} observed since the last drain."""
        import jax
        jax.effects_barrier()
        return self.mailbox.drain()

    # -- fault accounting ----------------------------------------------------

    def record_quarantine(self, site: str) -> None:
        self.quarantines += 1
        if obs.enabled():
            obs.counter("repro_guard_quarantine_total",
                        "requests evicted for poisoned state").inc(site=site)
        self.degrade()
        if self.cfg.max_quarantines is not None \
                and self.quarantines > self.cfg.max_quarantines:
            self.fail(f"quarantine budget exhausted "
                      f"({self.quarantines} > {self.cfg.max_quarantines})")

    def record_scrub(self, site: str) -> None:
        """Poison observed in an *unoccupied* slot — scrubbed, nobody
        evicted."""
        self.scrubs += 1
        if obs.enabled():
            obs.counter("repro_guard_scrub_total",
                        "idle-slot cache scrubs").inc(site=site)
        self.degrade()

    def record_retry(self) -> None:
        self.retries += 1
        if obs.enabled():
            obs.counter("repro_guard_step_retries_total",
                        "transient launch failures retried").inc()
        self.degrade()

    def record_expired(self, where: str, n: int = 1) -> None:
        self.expired += n
        if obs.enabled():
            obs.counter("repro_guard_expired_total",
                        "requests past their deadline").inc(n, where=where)

    def record_shed(self, reason: str) -> None:
        self.shed += 1
        if obs.enabled():
            obs.counter("repro_guard_shed_total",
                        "requests rejected at admission").inc(reason=reason)

    def maybe_verify_admit(self) -> bool:
        """Seeded coin flip for the verify-on-admit spot check."""
        p = self.cfg.verify_on_admit
        return p > 0 and bool(self._rng.random() < p)

    def summary(self) -> dict:
        return {
            "state": self.state,
            "quarantines": self.quarantines,
            "scrubs": self.scrubs,
            "retries": self.retries,
            "watchdog_trips": self.watchdog_trips,
            "expired": self.expired,
            "shed": self.shed,
            "degraded_steps": self.degraded_steps,
            "fail_reason": self.fail_reason,
        }


# ---------------------------------------------------------------------------
# Packed-stream verification with graceful degradation
# ---------------------------------------------------------------------------

def verify_packed_tree(packed, cfg=None, source_params=None,
                       repair: bool = True):
    """Codec stream validation over a packed weight tree, with repair.

    Returns ``(tree, repairs)`` where ``repairs`` is a list of
    ``(leaf path, mode)`` — empty when every stream was already intact (the
    common case; then ``tree is packed``). Repair modes, best first:

    ``requantize``
        ``source_params`` (the dense tree) and ``cfg`` given: re-pack the
        source and splice the fresh leaves over the broken ones. Encoders
        are deterministic, so this is an exact restore.
    ``clamp``
        No source available but the damage is confined to u8 scale bytes:
        clamp them into the codec's legal range. Values decode wrong by a
        bounded factor instead of exploding to inf/NaN — degraded, not
        poisoned.

    Anything else raises :class:`StreamIntegrityError` naming the leaves.
    Metrics: ``repro_guard_stream_invalid_total{stage="weights"}`` per bad
    leaf, ``repro_guard_stream_repair_total{mode}`` per repair.
    """
    import jax
    from repro.core.codecs import (PackedTensor, get_codec, validate_packed,
                                   validate_packed_tree)

    report = validate_packed_tree(packed)
    if not report:
        return packed, []
    if obs.enabled():
        obs.counter("repro_guard_stream_invalid_total",
                    "packed leaves failing codec stream validation").inc(
            len(report), stage="weights")
    if not repair:
        detail = "; ".join(f"{k}: {'; '.join(v)}"
                           for k, v in sorted(report.items()))
        raise StreamIntegrityError(
            f"{len(report)} packed leaf(s) violate codec stream invariants "
            f"and repair is disabled ({detail})", leaves=report)

    is_packed = lambda x: isinstance(x, PackedTensor)  # noqa: E731

    def _key(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    fresh_by_key = {}
    if source_params is not None and cfg is not None:
        from repro.serve.prequant import prequantize_params
        fresh = prequantize_params(source_params, cfg)
        fresh_by_key = {_key(p): leaf for p, leaf in
                        jax.tree_util.tree_flatten_with_path(
                            fresh, is_leaf=is_packed)[0]}

    repairs, unrepairable = [], {}
    flat, tdef = jax.tree_util.tree_flatten_with_path(packed,
                                                      is_leaf=is_packed)
    leaves = []
    for path, leaf in flat:
        key = _key(path)
        if key not in report:
            leaves.append(leaf)
            continue
        if key in fresh_by_key:
            leaves.append(fresh_by_key[key])
            repairs.append((key, "requantize"))
            continue
        clamped = _clamp_scales(leaf, get_codec(leaf.codec))
        if clamped is not None and not validate_packed(clamped):
            leaves.append(clamped)
            repairs.append((key, "clamp"))
        else:
            leaves.append(leaf)
            unrepairable[key] = report[key]
    if unrepairable:
        detail = "; ".join(f"{k}: {'; '.join(v)}"
                           for k, v in sorted(unrepairable.items()))
        raise StreamIntegrityError(
            f"{len(unrepairable)} packed leaf(s) are corrupt beyond scale "
            f"clamping and no source weights were given to re-quantize "
            f"from ({detail}); re-run prequantize_checkpoint",
            leaves=unrepairable)
    if obs.enabled():
        for _, mode in repairs:
            obs.counter("repro_guard_stream_repair_total",
                        "packed-leaf repairs by mode").inc(mode=mode)
    return jax.tree_util.tree_unflatten(tdef, leaves), repairs


def _clamp_scales(p, codec):
    """Clamp a packed tensor's u8 scale bytes into the codec's legal range;
    None if the codec has no u8 scale stream to clamp."""
    import jax.numpy as jnp
    sc = p.streams.get("scales")
    if sc is None or sc.dtype != jnp.uint8:
        return None
    if codec.scale_kind == "e8m0":
        fixed = jnp.clip(sc, 1, 254)
    elif codec.scale_kind == "e4m3":
        # pull NaN patterns (x7F/xFF) down to the e4m3 max-normal x7E/xFE
        nan = (sc & 0x7F) == 0x7F
        fixed = jnp.where(nan, sc - 1, sc)
    else:
        return None
    streams = dict(p.streams)
    streams["scales"] = fixed
    return type(p)(streams, p.shape, p.codec)
