# Packed-weight serving: offline prequantization to M2XFP streams, a
# continuous-batching slot scheduler, and the batched decode engine
# (paper Sec. 5 deployment path — weights stay 4.5 bits/elem in HBM).
from .engine import ServeEngine, ServeStats, tree_nbytes  # noqa: F401
from .prequant import (  # noqa: F401
    load_packed_checkpoint, packed_template, prequantize_checkpoint,
    prequantize_params, save_packed_checkpoint,
)
from .scheduler import Request, SlotScheduler  # noqa: F401
