# Packed-weight serving: offline prequantization to M2XFP streams, a
# continuous-batching slot scheduler, and the batched decode engine
# (paper Sec. 5 deployment path — weights stay 4.5 bits/elem in HBM).
# Fault tolerance (guard): poison sentinels, quarantine, deadlines,
# backpressure — see docs/robustness.md.
from .engine import ServeEngine, ServeStats, tree_nbytes  # noqa: F401
from .guard import (  # noqa: F401
    DEGRADED, FAILED, HEALTHY, EngineFailedError, EngineGuard, GuardConfig,
    StreamIntegrityError, TransientStepError, verify_packed_tree,
)
from .prequant import (  # noqa: F401
    load_packed_checkpoint, packed_template, prequantize_checkpoint,
    prequantize_params, save_packed_checkpoint,
)
from .scheduler import (  # noqa: F401
    AdmissionError, Request, SlotScheduler,
)

__all__ = [
    "AdmissionError", "DEGRADED", "EngineFailedError", "EngineGuard",
    "FAILED", "GuardConfig", "HEALTHY", "Request", "ServeEngine",
    "ServeStats", "SlotScheduler", "StreamIntegrityError",
    "TransientStepError", "load_packed_checkpoint", "packed_template",
    "prequantize_checkpoint", "prequantize_params", "save_packed_checkpoint",
    "tree_nbytes", "verify_packed_tree",
]
