"""Offline prequantization: bf16 checkpoint -> packed codec checkpoint.

The serving engine must never rematerialize weights in bf16 in HBM, so the
bf16 -> packed conversion happens once, offline, with the codec named by
``cfg.quant_format`` (m2xfp: u8 codes + E8M0 scales + 2-bit meta, 4.5
bits/element), and the *packed* streams are what the checkpoint stores and
what the engine loads. ``PackedTensor`` is a registered pytree, so the
packed tree flows through ``repro.checkpoint`` unchanged — leaves are keyed
``<path>/.codes`` / ``.scales`` / ... per stream.

The manifest records the packed-format version AND the codec name;
``load_packed_checkpoint`` refuses a checkpoint whose codec does not match
``cfg.quant_format`` (the packed streams of different codecs are not
interchangeable), with an actionable message.

    params  = init_params(key, cfg)                  # or restore_state(...)
    packed  = prequantize_params(params, cfg)
    save_packed_checkpoint("ckpt/packed", packed, cfg)
    ...
    packed2 = load_packed_checkpoint("ckpt/packed", cfg)   # bit-identical

``load_packed_checkpoint`` builds the restore template with
``jax.eval_shape`` — no dense weights are ever allocated on the load path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.checkpoint import read_manifest, restore_state, save_state
from repro.models.model import init_params, pack_params_for_serving

__all__ = [
    "prequantize_params", "packed_template", "save_packed_checkpoint",
    "load_packed_checkpoint", "prequantize_checkpoint",
]

# v1 predates the codec registry and implies codec="m2xfp"; v2 records the
# codec explicitly in the manifest; v3 additionally carries a per-leaf
# CRC-32 (written by repro.checkpoint for every save — the version bump
# just marks that integrity metadata is guaranteed present). v1/v2
# checkpoints still load; they simply restore unverified.
_PACKED_TAG = "mx-packed"
_PACKED_VERSION = 3
_LEGACY_TAG = "m2xfp-packed-v1"


def _serve_cfg(cfg):
    return cfg if cfg.quant == "serve" else \
        dataclasses.replace(cfg, quant="serve")


def prequantize_params(params: dict, cfg) -> dict:
    """Dense param tree -> packed tree in ``cfg.quant_format`` (every GEMM
    weight becomes a codec-tagged ``PackedTensor``; embeddings / norms /
    recurrence params stay bf16)."""
    return pack_params_for_serving(params, _serve_cfg(cfg))


def packed_template(cfg) -> dict:
    """Abstract (ShapeDtypeStruct) packed tree for checkpoint restore —
    computed with eval_shape, so no weight memory is allocated."""
    scfg = _serve_cfg(cfg)

    def build(key):
        return pack_params_for_serving(init_params(key, scfg), scfg)

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def save_packed_checkpoint(ckpt_dir: str, packed: dict, cfg,
                           step: int = 0, extra: Optional[dict] = None,
                           keep: int = 3) -> str:
    """Atomic save of a packed tree via repro.checkpoint. Returns the
    checkpoint directory."""
    meta = {"format": _PACKED_TAG, "format_version": _PACKED_VERSION,
            "codec": cfg.quant_format, "model": cfg.name}
    meta.update(extra or {})
    return save_state(ckpt_dir, step, packed, extra=meta, keep=keep)


def load_packed_checkpoint(ckpt_dir: str, cfg,
                           step: Optional[int] = None,
                           shardings=None, verify: bool = True,
                           validate_streams: bool = False) -> Tuple[dict, dict]:
    """Restore a packed tree. Returns (packed, manifest_extra); raises if
    the checkpoint was not written by ``save_packed_checkpoint`` or was
    packed with a different codec than ``cfg.quant_format``.

    ``verify``: per-leaf CRC-32 verification against the manifest (format
    v3; older manifests restore unverified) — a flipped byte raises
    :class:`repro.checkpoint.CheckpointCorruptError` naming the leaf.
    ``validate_streams``: additionally run the codec's semantic stream
    validation (E8M0 scale-byte range etc., ``repro.core.codecs
    .validate_packed_tree``) on the restored tree and raise ``ValueError``
    listing the offending leaves — catches corruption that happened
    *before* the checkpoint was written and so passes CRC."""
    extra = read_manifest(ckpt_dir, step).get("extra", {})
    tag = extra.get("format")
    if tag == _LEGACY_TAG:
        codec = "m2xfp"                    # v1 manifests predate the field
    elif tag == _PACKED_TAG:
        codec = extra.get("codec")
        if codec is None:
            raise ValueError(
                f"{ckpt_dir} is a packed checkpoint (format={tag!r} "
                f"v{extra.get('format_version')}) but its manifest records "
                f"no codec; re-run prequantize_checkpoint to rewrite it")
    else:
        raise ValueError(
            f"{ckpt_dir} is not a packed checkpoint (format={tag!r}); "
            f"run prequantize_checkpoint first")
    if codec != cfg.quant_format:
        raise ValueError(
            f"{ckpt_dir} was packed with codec {codec!r} but "
            f"cfg.quant_format={cfg.quant_format!r}; packed streams are "
            f"not interchangeable between codecs — load with a matching "
            f"config (dataclasses.replace(cfg, quant_format={codec!r})) "
            f"or re-run prequantize_checkpoint with this one")
    packed, manifest_extra = restore_state(
        ckpt_dir, packed_template(cfg), step, shardings, verify=verify)
    if validate_streams:
        from repro.core.codecs import validate_packed_tree
        report = validate_packed_tree(packed)
        if report:
            detail = "; ".join(f"{k}: {'; '.join(v)}"
                               for k, v in sorted(report.items()))
            raise ValueError(
                f"{ckpt_dir} restored but {len(report)} packed leaf(s) "
                f"violate codec stream invariants ({detail}); re-run "
                f"prequantize_checkpoint from source weights")
    return packed, manifest_extra


def prequantize_checkpoint(src_dir: str, dst_dir: str, cfg,
                           step: Optional[int] = None,
                           keep: int = 3) -> str:
    """Offline pass: read a dense bf16 checkpoint, pack every GEMM weight
    to Sg-EM streams, write a packed checkpoint. The only time dense
    weights exist in memory is inside this converter."""
    template = jax.eval_shape(
        lambda key: init_params(key, cfg), jax.random.PRNGKey(0))
    src_step = read_manifest(src_dir, step)["step"]
    params, _ = restore_state(src_dir, template, src_step)
    packed = prequantize_params(params, cfg)
    return save_packed_checkpoint(
        dst_dir, packed, cfg, step=src_step,
        extra={"source": src_dir}, keep=keep)
