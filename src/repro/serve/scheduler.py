"""Continuous-batching request scheduler (host-side, no jax).

The serving engine holds a fixed number of *slots* — rows of the batched
decode step and of the paged KV cache. Requests queue in FIFO order; a
request is admitted when a slot frees up and evicted the step it finishes.
Decode steps never stall on stragglers: a long request keeps its slot while
short requests cycle through the others (continuous batching).

Request-lifecycle hardening (the fault-tolerance layer, see
``repro.serve.guard`` and docs/robustness.md):

  * ``submit`` validates requests up front — empty prompt, prompt longer
    than a cache page, non-positive ``max_new_tokens`` — and rejects with a
    clear ``ValueError`` instead of undefined slot behaviour later.
  * The admission queue is optionally bounded (``max_queue``): a full
    queue raises :class:`AdmissionError` with an explicit reason
    (backpressure/shedding) instead of growing without bound.
  * Requests may carry a deadline (``ttl_steps``, engine steps from
    submission); :meth:`expire` evicts overdue requests — queued or
    running — into the ``expired`` list so one stuck client cannot pin a
    slot forever.
  * Besides FINISHED, a request can end QUARANTINED (its slot produced
    non-finite values or corrupt KV bytes — ``SlotScheduler.quarantine``)
    or EXPIRED (deadline). Terminal requests record ``fail_reason``.

Invariants (checked by ``SlotScheduler.check``):
  * free slots and active slots partition [0, n_slots)
  * every active slot maps to exactly one RUNNING request
  * queued requests are QUEUED and hold no slot
  * finished/quarantined/expired requests are terminal and hold no slot
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["Request", "SlotScheduler", "AdmissionError", "QUEUED", "RUNNING",
           "FINISHED", "QUARANTINED", "EXPIRED", "PREFILL", "DECODE"]

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"
QUARANTINED, EXPIRED = "quarantined", "expired"
_TERMINAL = (FINISHED, QUARANTINED, EXPIRED)
PREFILL, DECODE = "prefill", "decode"


class AdmissionError(RuntimeError):
    """A request was rejected at submission (backpressure). ``reason`` is a
    stable machine-readable tag (``queue_full``); the message says what the
    client should do (back off and retry, or raise ``max_queue``)."""

    def __init__(self, message: str, reason: str):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass
class Request:
    """One generation request.

    A running request moves through two phases: **prefill**, while
    ``consumed`` (prompt tokens fed to the model) is short of the prompt —
    the engine feeds up to ``prefill_chunk`` prompt tokens per step — then
    **decode**, where each step appends one sampled token to ``output``
    until ``max_new_tokens`` (or ``eos_id``).

    ``ttl_steps``: optional deadline in engine steps measured from
    ``submit_step``; the scheduler expires the request (queued or running)
    once the deadline passes. ``fail_reason`` records why a request ended
    QUARANTINED or EXPIRED.
    """

    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    state: str = QUEUED
    consumed: int = 0               # prompt tokens fed so far
    submit_step: int = 0
    ttl_steps: Optional[int] = None
    admit_step: int = -1
    first_token_step: int = -1      # engine step that sampled output[0]
    finish_step: int = -1
    fail_reason: str = ""

    @property
    def phase(self) -> str:
        """'prefill' while prompt tokens remain to feed, else 'decode'."""
        return PREFILL if self.consumed < len(self.prompt) else DECODE

    @property
    def done(self) -> bool:
        if len(self.output) >= self.max_new_tokens:
            return True
        return bool(self.output) and self.output[-1] == self.eos_id

    @property
    def ttft_steps(self) -> int:
        """Engine steps from admission to the first sampled token."""
        if self.first_token_step < 0:
            return -1
        return self.first_token_step - self.admit_step

    def overdue(self, step: int) -> bool:
        """True once ``ttl_steps`` engine steps have passed since submit."""
        return (self.ttl_steps is not None
                and step - self.submit_step >= self.ttl_steps)


class SlotScheduler:
    """FIFO admit / immediate-evict slot scheduler.

    ``max_queue``: bound on waiting requests (None = unbounded, the
    pre-hardening behaviour); a full queue rejects with
    :class:`AdmissionError` (the engine counts these as shed requests).
    ``max_prompt_len``: bound on prompt length (None = unchecked) — the
    engine passes its cache-page capacity so an oversized prompt fails at
    submit instead of corrupting a slot's position track.
    """

    def __init__(self, n_slots: int, max_queue: Optional[int] = None,
                 max_prompt_len: Optional[int] = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.max_prompt_len = max_prompt_len
        self.free: List[int] = list(range(n_slots))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.quarantined: List[Request] = []
        self.expired: List[Request] = []
        self._rid = itertools.count()

    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None,
               ttl_steps: Optional[int] = None, step: int = 0) -> Request:
        if not prompt:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens}: a request must ask for "
                f"at least one generated token")
        if self.max_prompt_len is not None \
                and len(prompt) > self.max_prompt_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the cache page "
                f"capacity {self.max_prompt_len}; split the prompt or "
                f"serve with a larger max_len")
        if ttl_steps is not None and ttl_steps < 1:
            raise ValueError(f"ttl_steps={ttl_steps}: deadline must be >= 1 "
                             f"engine step (or None)")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise AdmissionError(
                f"admission queue full ({len(self.queue)}/{self.max_queue} "
                f"waiting): shedding request instead of queueing unbounded "
                f"— back off and retry, or serve with a larger max_queue",
                reason="queue_full")
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      ttl_steps=ttl_steps, submit_step=step)
        self.queue.append(req)
        return req

    def admit(self, step: int = 0) -> List[Request]:
        """Move queued requests into free slots (FIFO). Returns the newly
        admitted requests, each with ``req.slot`` assigned."""
        admitted = []
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.pop(0)
            req.slot, req.state, req.admit_step = slot, RUNNING, step
            self.active[slot] = req
            admitted.append(req)
        return admitted

    def _release(self, slot: int, step: int, state: str, into: List[Request],
                 reason: str = "") -> Request:
        req = self.active.pop(slot)
        req.state, req.finish_step, req.slot = state, step, None
        req.fail_reason = reason
        self.free.append(slot)
        into.append(req)
        return req

    def evict(self, slot: int, step: int = 0) -> Request:
        """Release a slot; its request is FINISHED and the slot is free."""
        return self._release(slot, step, FINISHED, self.finished)

    def quarantine(self, slot: int, step: int = 0,
                   reason: str = "poisoned") -> Request:
        """Release a slot whose launch produced poisoned values (NaN/Inf
        logits, corrupt KV bytes). The request ends QUARANTINED — it is
        NOT retried (its cache state is unrecoverable) and never joins
        ``finished``; the slot is free for the next admission once the
        engine scrubs its cache rows."""
        return self._release(slot, step, QUARANTINED, self.quarantined,
                             reason=reason)

    def expire(self, step: int) -> List[Request]:
        """Evict every overdue request (deadline ``ttl_steps`` passed since
        submission), queued or running, into ``expired``. Returns them."""
        out = []
        for slot, req in list(self.active.items()):
            if req.overdue(step):
                out.append(self._release(slot, step, EXPIRED, self.expired,
                                         reason="deadline_running"))
        still = deque()
        for req in self.queue:
            if req.overdue(step):
                req.state, req.finish_step = EXPIRED, step
                req.fail_reason = "deadline_queued"
                self.expired.append(req)
                out.append(req)
            else:
                still.append(req)
        self.queue = still
        return out

    def plan_chunks(self, max_chunk: int,
                    token_budget: Optional[int] = None) -> Dict[int, int]:
        """Per-slot token counts for the engine's next step — the
        prefill/decode mixing policy.

        Decode-phase slots always get 1 (their next sampled token is never
        starved by prefill work). Prefill-phase slots split ``token_budget``
        prompt tokens per step (None = unlimited), oldest admission first,
        each receiving up to ``max_chunk`` tokens; the oldest prefilling
        request always receives at least one token even when the budget is
        exhausted (liveness). A slot may be planned 0 tokens (budget
        starvation) — the engine masks it out of the launch entirely."""
        if max_chunk < 1:
            raise ValueError("max_chunk must be >= 1")
        plan: Dict[int, int] = {}
        prefilling = []
        for slot, req in self.active.items():
            if req.phase == DECODE:
                plan[slot] = 1
            else:
                prefilling.append(req)
        prefilling.sort(key=lambda r: (r.admit_step, r.rid))
        remaining = token_budget
        for i, req in enumerate(prefilling):
            want = min(max_chunk, len(req.prompt) - req.consumed)
            if remaining is None:
                give = want
            else:
                give = min(want, remaining)
                if i == 0:
                    give = max(give, 1)          # liveness floor
                remaining = max(0, remaining - give)
            plan[req.slot] = give
        return plan

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    @property
    def occupancy(self) -> float:
        return len(self.active) / self.n_slots

    def check(self) -> None:
        """Assert the scheduler invariants (used by tests)."""
        assert sorted(self.free + list(self.active)) == sorted(
            set(self.free) | set(self.active)), "slot listed twice"
        assert set(self.free).isdisjoint(self.active), "free ∩ active"
        assert set(self.free) | set(self.active) == set(range(self.n_slots))
        for slot, req in self.active.items():
            assert req.slot == slot and req.state == RUNNING
            assert 0 <= req.consumed <= len(req.prompt), "consumed overran"
        for req in self.queue:
            assert req.slot is None and req.state == QUEUED
            assert req.consumed == 0 and not req.output
        for req in self.finished:
            assert req.slot is None and req.state == FINISHED
        for req in self.quarantined:
            assert req.slot is None and req.state == QUARANTINED
            assert req.fail_reason, "quarantine without a reason"
        for req in self.expired:
            assert req.slot is None and req.state == EXPIRED
            assert req.fail_reason.startswith("deadline")
