"""Continuous-batching request scheduler (host-side, no jax).

The serving engine holds a fixed number of *slots* — rows of the batched
decode step and of the paged KV cache. Requests queue in FIFO order; a
request is admitted when a slot frees up and evicted the step it finishes.
Decode steps never stall on stragglers: a long request keeps its slot while
short requests cycle through the others (continuous batching).

Invariants (checked by ``SlotScheduler.check``):
  * free slots and active slots partition [0, n_slots)
  * every active slot maps to exactly one RUNNING request
  * queued requests are QUEUED and hold no slot
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["Request", "SlotScheduler", "QUEUED", "RUNNING", "FINISHED",
           "PREFILL", "DECODE"]

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"
PREFILL, DECODE = "prefill", "decode"


@dataclasses.dataclass
class Request:
    """One generation request.

    A running request moves through two phases: **prefill**, while
    ``consumed`` (prompt tokens fed to the model) is short of the prompt —
    the engine feeds up to ``prefill_chunk`` prompt tokens per step — then
    **decode**, where each step appends one sampled token to ``output``
    until ``max_new_tokens`` (or ``eos_id``).
    """

    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    state: str = QUEUED
    consumed: int = 0               # prompt tokens fed so far
    admit_step: int = -1
    first_token_step: int = -1      # engine step that sampled output[0]
    finish_step: int = -1

    @property
    def phase(self) -> str:
        """'prefill' while prompt tokens remain to feed, else 'decode'."""
        return PREFILL if self.consumed < len(self.prompt) else DECODE

    @property
    def done(self) -> bool:
        if len(self.output) >= self.max_new_tokens:
            return True
        return bool(self.output) and self.output[-1] == self.eos_id

    @property
    def ttft_steps(self) -> int:
        """Engine steps from admission to the first sampled token."""
        if self.first_token_step < 0:
            return -1
        return self.first_token_step - self.admit_step


class SlotScheduler:
    """FIFO admit / immediate-evict slot scheduler."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.free: List[int] = list(range(n_slots))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self._rid = itertools.count()

    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        if not prompt:
            raise ValueError("empty prompt")
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id)
        self.queue.append(req)
        return req

    def admit(self, step: int = 0) -> List[Request]:
        """Move queued requests into free slots (FIFO). Returns the newly
        admitted requests, each with ``req.slot`` assigned."""
        admitted = []
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.pop(0)
            req.slot, req.state, req.admit_step = slot, RUNNING, step
            self.active[slot] = req
            admitted.append(req)
        return admitted

    def evict(self, slot: int, step: int = 0) -> Request:
        """Release a slot; its request is FINISHED and the slot is free."""
        req = self.active.pop(slot)
        req.state, req.finish_step, req.slot = FINISHED, step, None
        self.free.append(slot)
        self.finished.append(req)
        return req

    def plan_chunks(self, max_chunk: int,
                    token_budget: Optional[int] = None) -> Dict[int, int]:
        """Per-slot token counts for the engine's next step — the
        prefill/decode mixing policy.

        Decode-phase slots always get 1 (their next sampled token is never
        starved by prefill work). Prefill-phase slots split ``token_budget``
        prompt tokens per step (None = unlimited), oldest admission first,
        each receiving up to ``max_chunk`` tokens; the oldest prefilling
        request always receives at least one token even when the budget is
        exhausted (liveness). A slot may be planned 0 tokens (budget
        starvation) — the engine masks it out of the launch entirely."""
        if max_chunk < 1:
            raise ValueError("max_chunk must be >= 1")
        plan: Dict[int, int] = {}
        prefilling = []
        for slot, req in self.active.items():
            if req.phase == DECODE:
                plan[slot] = 1
            else:
                prefilling.append(req)
        prefilling.sort(key=lambda r: (r.admit_step, r.rid))
        remaining = token_budget
        for i, req in enumerate(prefilling):
            want = min(max_chunk, len(req.prompt) - req.consumed)
            if remaining is None:
                give = want
            else:
                give = min(want, remaining)
                if i == 0:
                    give = max(give, 1)          # liveness floor
                remaining = max(0, remaining - give)
            plan[req.slot] = give
        return plan

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    @property
    def occupancy(self) -> float:
        return len(self.active) / self.n_slots

    def check(self) -> None:
        """Assert the scheduler invariants (used by tests)."""
        assert sorted(self.free + list(self.active)) == sorted(
            set(self.free) | set(self.active)), "slot listed twice"
        assert set(self.free).isdisjoint(self.active), "free ∩ active"
        assert set(self.free) | set(self.active) == set(range(self.n_slots))
        for slot, req in self.active.items():
            assert req.slot == slot and req.state == RUNNING
            assert 0 <= req.consumed <= len(req.prompt), "consumed overran"
        for req in self.queue:
            assert req.slot is None and req.state == QUEUED
            assert req.consumed == 0 and not req.output
        for req in self.finished:
            assert req.slot is None and req.state == FINISHED
