"""Batched autoregressive serving engine over packed MX-family weights.

The engine owns:
  * a packed parameter tree (``repro.serve.prequant`` / checkpoint load) —
    every GEMM weight resident in HBM as the codec-tagged u8 streams of
    ``cfg.quant_format`` (any ``repro.core.codecs`` entry with an encoder:
    m2xfp at 4.5 bits/element, mxfp4, nvfp4, ...), decoded inline by the
    quantized matmul (codec Pallas kernel on TPU, XLA decode mirror
    otherwise — see repro.models.quant);
  * a paged KV cache: ``init_caches(..., per_slot=True)`` — batch row b is
    request slot b, a fixed-size page of the cache pool with its own
    position track, admitted/evicted independently (continuous batching);
    with ``cfg.kv_quant`` set to a KV-capable codec pages hold its packed
    streams (m2xfp: Sg-EM codes/scales/meta);
  * a host-side ``SlotScheduler`` deciding which request occupies which
    slot each step and how many tokens each slot consumes.

Every step runs ONE jitted launch over all slots. Slots in the decode
phase consume one token each; newly admitted requests **prefill in chunks**
of up to ``prefill_chunk`` prompt tokens per step through
``repro.models.model.prefill_chunk`` — the packed weight streams cross HBM
once per chunk instead of once per prompt token, which is what makes
time-to-first-token scale with ``ceil(prompt / chunk)`` instead of
``prompt``. A mixed step (some slots prefilling, some decoding) is a single
``prefill_chunk`` launch with a per-slot chunk-length vector: decode slots
carry length 1, idle slots length 0 (masked out of every cache write). When
every planned length is 1 the engine uses the plain ``decode_step`` launch.
Both paths are bit-identical per token — pinned by tests/test_serve.py.

The scheduler's ``plan_chunks`` token-budget policy caps total prefill
tokens per step so a long prompt cannot starve decoding neighbours.
Slots whose request finished keep ticking on a dummy token until the
scheduler refills them; admit-time reset invalidates the slot's position
track (which masks every stale KV entry) and re-initializes recurrent
state, so no state leaks between requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as _model
from repro.models.model import decode_step, init_caches

from . import guard as _guard
from .guard import (EngineFailedError, EngineGuard, GuardConfig,
                    TransientStepError)
from .scheduler import AdmissionError, Request, SlotScheduler

# TTFT is quantized in engine steps; buckets cover 1..256-step prompts
_TTFT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)

__all__ = ["ServeEngine", "ServeStats", "tree_nbytes"]


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (what the tree keeps resident)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclasses.dataclass
class ServeStats:
    n_slots: int = 1
    steps: int = 0                 # launches (decode or mixed prefill)
    decode_steps: int = 0          # pure one-token launches
    prefill_steps: int = 0         # launches that carried prefill chunks
    slot_steps: int = 0            # sum over steps of slots making progress
    prefill_tokens: int = 0        # prompt tokens fed (excl. sampling step)
    generated_tokens: int = 0      # tokens sampled and returned
    wall_s: float = 0.0
    prefill_wall_s: float = 0.0    # wall attributed to prefill launches
    decode_wall_s: float = 0.0     # wall attributed to pure decode launches
    quarantined: int = 0           # requests evicted for poisoned state
    expired: int = 0               # requests past their deadline
    shed: int = 0                  # requests rejected at admission

    @property
    def tokens_per_sec(self) -> float:
        total = self.prefill_tokens + self.generated_tokens
        return total / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def prefill_tokens_per_sec(self) -> float:
        if self.prefill_wall_s <= 0:
            return 0.0
        return self.prefill_tokens / self.prefill_wall_s

    @property
    def decode_tokens_per_sec(self) -> float:
        if self.decode_wall_s <= 0:
            return 0.0
        return self.generated_tokens / self.decode_wall_s

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per step."""
        if not self.steps:
            return 0.0
        return self.slot_steps / (self.steps * self.n_slots)

    def to_dict(self) -> dict:
        """Every field plus every derived property, as plain floats/ints —
        what benches and the obs JSONL sink serialize (no poking at
        dataclass internals)."""
        out = dataclasses.asdict(self)
        out.update(
            tokens_per_sec=self.tokens_per_sec,
            prefill_tokens_per_sec=self.prefill_tokens_per_sec,
            decode_tokens_per_sec=self.decode_tokens_per_sec,
            occupancy=self.occupancy,
        )
        return out


def _greedy(logits: np.ndarray) -> np.ndarray:
    """(B, V) -> (B,) argmax token ids."""
    return np.argmax(logits, axis=-1).astype(np.int32)


def _reset_slot(caches: dict, slot: jax.Array, scrub: bool = False) -> dict:
    """Return ``caches`` with one slot's rows back in their init state.

    Every cache leaf is layer-stacked with the slot (batch) axis second.
    For a normal admit-time reset attention K/V pages need no scrub —
    setting the slot's position track to -1 masks every stale entry
    (``attention_decode``'s valid test), so only the position rows and the
    recurrent-state rows are written. ``m`` is the mlstm/slstm running
    log-max, initialized to -1e30.

    ``scrub=True`` (quarantine path) additionally zeroes the slot's K/V
    pages and packed-KV streams: a poisoned page (NaN float, reserved
    scale byte 255) would re-trip the KV sentinel every subsequent step if
    left masked-but-resident. Zero is the init state of every page stream
    (packed-KV scale byte 0 = empty page)."""
    def fix(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        if name == "pos":
            return leaf.at[:, slot].set(-1)
        if any(k in ("mlstm", "slstm", "mamba") for k in keys):
            fill = -1e30 if name == "m" else 0.0
            return leaf.at[:, slot].set(jnp.asarray(fill, leaf.dtype))
        if scrub:
            return leaf.at[:, slot].set(jnp.zeros((), leaf.dtype))
        return leaf                        # K/V pages: masked via pos
    return jax.tree_util.tree_map_with_path(fix, caches)


class ServeEngine:
    """Continuous-batching decode engine. See module docstring.

    Parameters
    ----------
    params : packed parameter tree (``prequantize_params`` output) — or a
        dense tree if ``cfg.quant != 'serve'`` (useful for A/B parity runs).
    cfg : ModelConfig, normally with ``quant='serve'``.
    n_slots : batch width = number of concurrently served requests.
    max_len : cache capacity per slot (prompt + generated tokens; a
        sliding-window config bounds the page at the window instead).
    sample_fn : (B, V) float32 logits -> (B,) int32 token ids; greedy
        argmax by default (deterministic — what the parity tests pin).
    prefill_chunk : max prompt tokens consumed per slot per step. 1
        recovers the legacy one-token teacher forcing (and is forced for
        the recurrent ssm/hybrid families, whose per-token state updates
        cannot batch along the sequence).
    prefill_budget : cap on total prefill tokens per step across all slots
        (None = unlimited) so prefill-heavy traffic cannot starve decoding
        slots; the oldest prefilling request always progresses.
    guard : fault-tolerance config (``repro.serve.guard.GuardConfig``).
        None (default) = guard on with default knobs: NaN/poison sentinels
        traced into the launches, poisoned-slot quarantine, transient-step
        retries, health state machine. ``False`` = guard fully off — the
        launch graphs are byte-identical to the pre-guard engine.
    max_queue : bound on the admission queue (None = unbounded); a full
        queue sheds submissions with ``AdmissionError`` (backpressure).
    default_ttl_steps : deadline in engine steps applied to every request
        that does not carry its own ``ttl_steps`` (None = no deadline).
    verify_weights : run codec stream validation over the packed params at
        init, repairing broken leaves (re-quantize from ``source_params``
        when given, else clamp scales — see guard.verify_packed_tree).
    source_params : optional dense parameter tree enabling exact
        re-quantization repair of corrupt packed leaves.
    """

    def __init__(self, params, cfg, n_slots: int = 8, max_len: int = 256,
                 sample_fn: Optional[Callable] = None,
                 prefill_chunk: int = 8,
                 prefill_budget: Optional[int] = None,
                 guard=None, max_queue: Optional[int] = None,
                 default_ttl_steps: Optional[int] = None,
                 verify_weights: bool = False, source_params=None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample_fn = sample_fn or _greedy
        self.chunk = max(1, int(prefill_chunk))
        if cfg.family in ("ssm", "hybrid"):
            self.chunk = 1           # recurrent state updates token by token
        self.prefill_budget = prefill_budget
        if guard is False:
            gcfg = None
        else:
            gcfg = guard if isinstance(guard, GuardConfig) else GuardConfig()
        self.guard: Optional[EngineGuard] = \
            EngineGuard(gcfg) if gcfg is not None else None
        self.default_ttl_steps = default_ttl_steps
        self.source_params = source_params
        # sliding-window configs accept prompts longer than the page
        self.scheduler = SlotScheduler(
            n_slots, max_queue=max_queue,
            max_prompt_len=None if cfg.sliding_window else max_len)
        self.stats = ServeStats(n_slots=n_slots)

        if verify_weights:
            self.params, repairs = _guard.verify_packed_tree(
                params, cfg=cfg, source_params=source_params)
            if repairs and self.guard:
                # clamped leaves decode degraded (bounded error) — say so
                if any(mode == "clamp" for _, mode in repairs):
                    self.guard.degrade()

        self.caches = init_caches(cfg, n_slots, max_len, per_slot=True)
        # host-side per-slot state
        self._tokens = np.zeros((n_slots, 1), np.int32)   # last sampled token
        self._index = np.zeros((n_slots,), np.int32)      # absolute position

        # donate the cache pool: decode updates it in place instead of
        # materializing a second copy every step (2x HBM otherwise; CPU
        # ignores donation with a harmless warning). With the guard on, the
        # poison sentinels are traced into the same launch (per-slot
        # reductions + debug callback; numerics untouched — the golden-token
        # tests pin that).
        mailbox = self.guard.mailbox if self.guard else None
        nan_checks = bool(gcfg and gcfg.nan_checks)
        kv_checks = bool(gcfg and gcfg.kv_checks)

        def decode_fn(p, b, c, i):
            logits, c2 = decode_step(p, cfg, b, c, i)
            if nan_checks:
                # decode rows always attend over >= 1 valid entry (the
                # token just written), so no masking is needed
                _guard.probe_logits(mailbox, logits[:, -1])
            if kv_checks:
                _guard.probe_kv(mailbox, c2, n_slots)
            return logits, c2

        def prefill_fn(p, b, c, i, l):
            logits, c2 = _model.prefill_chunk(p, cfg, b, c, i, l)
            if nan_checks:
                # probe only the row each slot samples from; idle rows
                # (l == 0) legitimately softmax over an all-masked window
                rows = logits[jnp.arange(logits.shape[0]),
                              jnp.maximum(l - 1, 0)]
                _guard.probe_logits(mailbox, rows, lengths=l)
            if kv_checks:
                _guard.probe_kv(mailbox, c2, n_slots)
            return logits, c2

        self._sentinels_on = nan_checks or kv_checks
        self._step = jax.jit(decode_fn, donate_argnums=(2,))
        self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
        self._reset = jax.jit(_reset_slot, donate_argnums=(0,))
        self._scrub = jax.jit(lambda c, s: _reset_slot(c, s, scrub=True),
                              donate_argnums=(0,))

        # quantization-health sweep of the packed weights: per-layer clip
        # rate / scale saturation / meta modes / re-encode drift gauges,
        # once at startup (off the decode hot path)
        if obs.enabled("health"):
            with obs.span("serve.weight_health", cat="obs"):
                obs.quant_health.weight_tree_health(params)

    # -- request lifecycle -------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: Optional[int] = None,
               ttl_steps: Optional[int] = None) -> Request:
        """Queue a request; it is admitted when a slot frees up.

        Raises ``ValueError`` on an invalid request (empty prompt,
        non-positive ``max_new_tokens``, prompt over the cache page),
        :class:`AdmissionError` when the queue is full (backpressure —
        counted as shed), :class:`EngineFailedError` once the engine's
        fault budget is exhausted."""
        if self.guard:
            self.guard.check_alive()
        if prompt and len(prompt) + max_new_tokens > self.max_len \
                and not self.cfg.sliding_window:
            raise ValueError(
                f"prompt+generation {len(prompt)}+{max_new_tokens} exceeds "
                f"cache capacity {self.max_len}")
        if ttl_steps is None:
            ttl_steps = self.default_ttl_steps
        try:
            return self.scheduler.submit(
                list(prompt), max_new_tokens, eos_id,
                ttl_steps=ttl_steps, step=self.stats.steps)
        except AdmissionError as e:
            self.stats.shed += 1
            if self.guard:
                self.guard.record_shed(e.reason)
            raise

    def _admit(self) -> None:
        admitted = self.scheduler.admit(self.stats.steps)
        for req in admitted:
            slot = req.slot
            self.caches = self._reset(self.caches, jnp.int32(slot))
            self._index[slot] = 0
        if admitted and self.guard and self.guard.maybe_verify_admit():
            self._spot_check_weights()

    def _spot_check_weights(self) -> None:
        """verify_on_admit sampling: validate one random packed leaf's
        streams against its codec invariants; on damage, repair the whole
        tree (re-quantize from source when available, else clamp)."""
        from repro.core.codecs import PackedTensor, validate_packed
        leaves = [l for l in jax.tree.leaves(
            self.params, is_leaf=lambda x: isinstance(x, PackedTensor))
            if isinstance(l, PackedTensor)]
        if not leaves:
            return
        pick = int(self.guard._rng.integers(len(leaves)))
        if not validate_packed(leaves[pick]):
            return
        if obs.enabled():
            obs.counter("repro_guard_stream_invalid_total",
                        "packed leaves failing codec stream validation"
                        ).inc(stage="admit")
        self.params, _ = _guard.verify_packed_tree(
            self.params, cfg=self.cfg, source_params=self.source_params)
        self.guard.degrade()

    # -- decode loop -------------------------------------------------------

    def _launch_decode(self, chunks) -> np.ndarray:
        """One-token launch for every slot. Returns (B, V) f32 logits at
        each slot's (single) position."""
        for slot, req in self.scheduler.active.items():
            if req.phase == "prefill":
                self._tokens[slot, 0] = req.prompt[req.consumed]
        with obs.span("serve.kernel.dispatch", kind="decode_step",
                      slots=self.n_slots):
            logits, self.caches = self._step(
                self.params, {"tokens": jnp.asarray(self._tokens)},
                self.caches, jnp.asarray(self._index))
            out = np.asarray(logits[:, -1]).astype(np.float32)
        return out

    def _launch_prefill(self, chunks) -> np.ndarray:
        """Mixed chunked launch: prefilling slots consume their planned
        chunk, decode slots their next token, idle / budget-starved slots
        are masked out (length 0). Returns (B, V) f32 logits at each slot's
        last valid position."""
        toks = np.zeros((self.n_slots, self.chunk), np.int32)
        lens = np.zeros((self.n_slots,), np.int32)
        for slot, req in self.scheduler.active.items():
            c = chunks.get(slot, 0)
            if c == 0:
                continue
            lens[slot] = c
            if req.phase == "prefill":
                toks[slot, :c] = req.prompt[req.consumed:req.consumed + c]
            else:
                toks[slot, 0] = self._tokens[slot, 0]
        with obs.span("serve.kernel.dispatch", kind="prefill_chunk",
                      slots=self.n_slots, tokens=int(lens.sum())):
            logits, self.caches = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                jnp.asarray(self._index), jnp.asarray(lens))
            lg = np.asarray(logits).astype(np.float32)    # (B, T, V)
        return lg[np.arange(self.n_slots), np.maximum(lens - 1, 0)]

    def step(self) -> int:
        """Admit, plan per-slot chunks, run one batched launch, route
        tokens. Returns the number of requests that finished this step.

        Raises :class:`EngineFailedError` once the guard's fault budget is
        exhausted (FAILED state — transient failures persisted past the
        retry budget, or quarantines blew ``max_quarantines``)."""
        if self.guard:
            self.guard.check_alive()
        with obs.span("serve.step", step=self.stats.steps):
            return self._step_inner()

    def _guarded_launch(self, fn, chunks) -> np.ndarray:
        """Run a launch with the guard's transient-failure retry policy.
        Only :class:`TransientStepError` is retried — it is raised *before*
        the jitted call consumes its donated buffers, so re-running is
        safe. Anything else propagates."""
        if not self.guard:
            return fn(chunks)
        attempts = 0
        while True:
            try:
                return fn(chunks)
            except TransientStepError as e:
                if attempts >= self.guard.cfg.max_step_retries:
                    self.guard.fail(
                        f"transient step failure persisted after "
                        f"{attempts} retries: {e}")
                    raise EngineFailedError(
                        f"launch failed {attempts + 1} times "
                        f"({e}); engine is FAILED") from e
                self.guard.record_retry()
                time.sleep(self.guard.cfg.retry_backoff_s * (2 ** attempts))
                attempts += 1

    def _expire_deadlines(self) -> None:
        for req in self.scheduler.expire(self.stats.steps):
            self.stats.expired += 1
            where = ("running" if req.fail_reason == "deadline_running"
                     else "queued")
            if self.guard:
                self.guard.record_expired(where)
            obs.instant("serve.expire", rid=req.rid, where=where)

    def _contain_faults(self, chunks, rows: np.ndarray) -> None:
        """Poisoned-slot containment, between launch and token routing.

        Unions the in-jit sentinel counts (drained via effects_barrier)
        with a host-side non-finite scan of the sampled rows, then for
        every flagged slot: quarantine its request (if occupied), scrub
        its cache rows to init state, and mask it out of this step's
        routing. The other slots' rows are untouched — their tokens stay
        bit-identical to a fault-free run (batch-row independence)."""
        faults = self.guard.drain() if self._sentinels_on else {}
        poisoned = {}                              # slot -> first bad site
        kv = faults.get("kv")
        if kv is not None:
            for slot in np.nonzero(np.asarray(kv))[0]:
                poisoned[int(slot)] = "kv"
        lg = faults.get("logits")
        if lg is not None:
            for slot in np.nonzero(np.asarray(lg))[0]:
                if chunks.get(int(slot), 0) > 0:
                    poisoned.setdefault(int(slot), "logits")
        # host-side belt and braces (also covers guard configs that turned
        # the in-jit probes off)
        for slot in np.nonzero(~np.isfinite(rows).all(axis=-1))[0]:
            if chunks.get(int(slot), 0) > 0:
                poisoned.setdefault(int(slot), "logits")
        for slot, site in sorted(poisoned.items()):
            occupied = slot in self.scheduler.active
            self.caches = self._scrub(self.caches, jnp.int32(slot))
            self._index[slot] = 0
            self._tokens[slot, 0] = 0
            chunks[slot] = 0                       # no routing this step
            if occupied:
                req = self.scheduler.quarantine(
                    slot, self.stats.steps, reason=site)
                self.stats.quarantined += 1
                self.guard.record_quarantine(site)
                obs.instant("serve.quarantine", rid=req.rid, slot=slot,
                            site=site)
            else:
                self.guard.record_scrub(site)

    def _step_inner(self) -> int:
        self._expire_deadlines()
        with obs.span("serve.admit"):
            self._admit()
        if not self.scheduler.active:
            return 0
        with obs.span("serve.plan"):
            chunks = self.scheduler.plan_chunks(self.chunk,
                                                self.prefill_budget)
        decode_only = all(c == 1 for c in chunks.values())
        phase = "decode" if decode_only else "prefill"
        t0 = time.perf_counter()
        with obs.span(f"serve.phase.{phase}",
                      slots=len(self.scheduler.active)):
            launch = self._launch_decode if decode_only \
                else self._launch_prefill
            sampled_from = self._guarded_launch(launch, chunks)
        dt = time.perf_counter() - t0
        if self.guard:
            self._contain_faults(chunks, sampled_from)
        with obs.span("serve.sample"):
            sampled = self.sample_fn(sampled_from)

        finished = 0
        first_tokens, new_prefill, new_generated = [], 0, 0
        self.stats.steps += 1
        if decode_only:
            self.stats.decode_steps += 1
            self.stats.decode_wall_s += dt
        else:
            self.stats.prefill_steps += 1
            self.stats.prefill_wall_s += dt
        for slot, req in list(self.scheduler.active.items()):
            c = chunks.get(slot, 0)
            if c == 0:
                continue                       # budget-starved: no progress
            self.stats.slot_steps += 1
            if req.phase == "prefill":
                req.consumed += c
                still_prefilling = req.consumed < len(req.prompt)
                fed = c - (0 if still_prefilling else 1)
                self.stats.prefill_tokens += fed
                new_prefill += fed
                if still_prefilling:
                    self._index[slot] += c
                    continue                   # logits discarded
                # the chunk ended on the last prompt token: its logits
                # sample the first generated token
                self.stats.generated_tokens += 1
            else:
                self.stats.generated_tokens += 1
            new_generated += 1
            tok = int(sampled[slot])
            req.output.append(tok)
            if req.first_token_step < 0:
                req.first_token_step = self.stats.steps
                first_tokens.append(req)
            self._tokens[slot, 0] = tok
            self._index[slot] += c
            if req.done:
                self.scheduler.evict(slot, self.stats.steps)
                obs.instant("serve.evict", rid=req.rid)
                finished += 1
        if self.guard:
            self.guard.note_step(dt)
        if obs.enabled():
            self._record_step_metrics(phase, dt, first_tokens,
                                      new_prefill, new_generated, finished)
        return finished

    def _record_step_metrics(self, phase, dt, first_tokens, new_prefill,
                             new_generated, finished) -> None:
        obs.histogram("repro_serve_step_latency_seconds",
                      "wall seconds per engine launch").observe(
            dt, phase=phase)
        obs.counter("repro_serve_steps_total",
                    "engine launches").inc(phase=phase)
        if new_prefill:
            obs.counter("repro_serve_tokens_total",
                        "tokens through the engine").inc(
                new_prefill, kind="prefill")
        if new_generated:
            obs.counter("repro_serve_tokens_total", "").inc(
                new_generated, kind="generated")
        if finished:
            obs.counter("repro_serve_requests_finished_total",
                        "requests that completed").inc(finished)
        for req in first_tokens:
            obs.histogram("repro_serve_ttft_steps",
                          "engine steps from admission to first token",
                          buckets=_TTFT_BUCKETS).observe(req.ttft_steps)
        obs.gauge("repro_serve_queue_depth",
                  "requests waiting for a slot").set(
            len(self.scheduler.queue))
        obs.gauge("repro_serve_active_slots",
                  "slots holding a running request").set(
            len(self.scheduler.active))
        obs.gauge("repro_serve_occupancy",
                  "mean fraction of slots progressing per step").set(
            self.stats.occupancy)

    def run(self) -> List[Request]:
        """Step until queue and slots drain. Returns the requests that
        finished during *this* drain, in submission order."""
        already_done = len(self.scheduler.finished)
        t0 = time.perf_counter()
        with obs.span("serve.run", slots=self.n_slots):
            while self.scheduler.has_work:
                self.step()
        self.stats.wall_s += time.perf_counter() - t0
        obs.autodump()          # metrics.jsonl + trace.json -> REPRO_OBS_DIR
        return sorted(self.scheduler.finished[already_done:],
                      key=lambda r: r.rid)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int,
                 eos_id: Optional[int] = None) -> List[List[int]]:
        """Batch convenience: submit every prompt, drain, return outputs."""
        reqs = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        self.run()
        return [r.output for r in reqs]

    # -- accounting --------------------------------------------------------

    @property
    def health(self) -> str:
        """Current health state ('healthy' when the guard is off)."""
        return self.guard.state if self.guard else _guard.HEALTHY

    def guard_summary(self) -> dict:
        """Fault-accounting snapshot (state, quarantines, retries, ...);
        empty dict when the guard is off."""
        return self.guard.summary() if self.guard else {}

    def mean_ttft_steps(self) -> float:
        """Mean steps from admission to first sampled token over every
        request that produced output (chunked prefill drives this down from
        ~prompt_len to ~ceil(prompt_len / prefill_chunk))."""
        ttfts = [r.ttft_steps for r in self.scheduler.finished
                 if r.ttft_steps >= 0]
        ttfts += [r.ttft_steps for r in self.scheduler.active.values()
                  if r.ttft_steps >= 0]
        return float(np.mean(ttfts)) if ttfts else 0.0

    def weight_bytes(self) -> int:
        return tree_nbytes(self.params)

    def kv_bytes(self) -> int:
        return tree_nbytes(self.caches)
