"""Batched autoregressive serving engine over packed MX-family weights.

The engine owns:
  * a packed parameter tree (``repro.serve.prequant`` / checkpoint load) —
    every GEMM weight resident in HBM as the codec-tagged u8 streams of
    ``cfg.quant_format`` (any ``repro.core.codecs`` entry with an encoder:
    m2xfp at 4.5 bits/element, mxfp4, nvfp4, ...), decoded inline by the
    quantized matmul (codec Pallas kernel on TPU, XLA decode mirror
    otherwise — see repro.models.quant);
  * a paged KV cache: ``init_caches(..., per_slot=True)`` — batch row b is
    request slot b, a fixed-size page of the cache pool with its own
    position track, admitted/evicted independently (continuous batching);
    with ``cfg.kv_quant`` set to a KV-capable codec pages hold its packed
    streams (m2xfp: Sg-EM codes/scales/meta);
  * a host-side ``SlotScheduler`` deciding which request occupies which
    slot each step and how many tokens each slot consumes.

Every step runs ONE jitted launch over all slots. Slots in the decode
phase consume one token each; newly admitted requests **prefill in chunks**
of up to ``prefill_chunk`` prompt tokens per step through
``repro.models.model.prefill_chunk`` — the packed weight streams cross HBM
once per chunk instead of once per prompt token, which is what makes
time-to-first-token scale with ``ceil(prompt / chunk)`` instead of
``prompt``. A mixed step (some slots prefilling, some decoding) is a single
``prefill_chunk`` launch with a per-slot chunk-length vector: decode slots
carry length 1, idle slots length 0 (masked out of every cache write). When
every planned length is 1 the engine uses the plain ``decode_step`` launch.
Both paths are bit-identical per token — pinned by tests/test_serve.py.

The scheduler's ``plan_chunks`` token-budget policy caps total prefill
tokens per step so a long prompt cannot starve decoding neighbours.
Slots whose request finished keep ticking on a dummy token until the
scheduler refills them; admit-time reset invalidates the slot's position
track (which masks every stale KV entry) and re-initializes recurrent
state, so no state leaks between requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as _model
from repro.models.model import decode_step, init_caches

from .scheduler import Request, SlotScheduler

# TTFT is quantized in engine steps; buckets cover 1..256-step prompts
_TTFT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)

__all__ = ["ServeEngine", "ServeStats", "tree_nbytes"]


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (what the tree keeps resident)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclasses.dataclass
class ServeStats:
    n_slots: int = 1
    steps: int = 0                 # launches (decode or mixed prefill)
    decode_steps: int = 0          # pure one-token launches
    prefill_steps: int = 0         # launches that carried prefill chunks
    slot_steps: int = 0            # sum over steps of slots making progress
    prefill_tokens: int = 0        # prompt tokens fed (excl. sampling step)
    generated_tokens: int = 0      # tokens sampled and returned
    wall_s: float = 0.0
    prefill_wall_s: float = 0.0    # wall attributed to prefill launches
    decode_wall_s: float = 0.0     # wall attributed to pure decode launches

    @property
    def tokens_per_sec(self) -> float:
        total = self.prefill_tokens + self.generated_tokens
        return total / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def prefill_tokens_per_sec(self) -> float:
        if self.prefill_wall_s <= 0:
            return 0.0
        return self.prefill_tokens / self.prefill_wall_s

    @property
    def decode_tokens_per_sec(self) -> float:
        if self.decode_wall_s <= 0:
            return 0.0
        return self.generated_tokens / self.decode_wall_s

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per step."""
        if not self.steps:
            return 0.0
        return self.slot_steps / (self.steps * self.n_slots)

    def to_dict(self) -> dict:
        """Every field plus every derived property, as plain floats/ints —
        what benches and the obs JSONL sink serialize (no poking at
        dataclass internals)."""
        out = dataclasses.asdict(self)
        out.update(
            tokens_per_sec=self.tokens_per_sec,
            prefill_tokens_per_sec=self.prefill_tokens_per_sec,
            decode_tokens_per_sec=self.decode_tokens_per_sec,
            occupancy=self.occupancy,
        )
        return out


def _greedy(logits: np.ndarray) -> np.ndarray:
    """(B, V) -> (B,) argmax token ids."""
    return np.argmax(logits, axis=-1).astype(np.int32)


def _reset_slot(caches: dict, slot: jax.Array) -> dict:
    """Return ``caches`` with one slot's rows back in their init state.

    Every cache leaf is layer-stacked with the slot (batch) axis second.
    Attention K/V pages need no scrub — setting the slot's position track
    to -1 masks every stale entry (``attention_decode``'s valid test), so
    only the position rows and the recurrent-state rows are written.
    ``m`` is the mlstm/slstm running log-max, initialized to -1e30."""
    def fix(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        if name == "pos":
            return leaf.at[:, slot].set(-1)
        if any(k in ("mlstm", "slstm", "mamba") for k in keys):
            fill = -1e30 if name == "m" else 0.0
            return leaf.at[:, slot].set(jnp.asarray(fill, leaf.dtype))
        return leaf                        # K/V pages: masked via pos
    return jax.tree_util.tree_map_with_path(fix, caches)


class ServeEngine:
    """Continuous-batching decode engine. See module docstring.

    Parameters
    ----------
    params : packed parameter tree (``prequantize_params`` output) — or a
        dense tree if ``cfg.quant != 'serve'`` (useful for A/B parity runs).
    cfg : ModelConfig, normally with ``quant='serve'``.
    n_slots : batch width = number of concurrently served requests.
    max_len : cache capacity per slot (prompt + generated tokens; a
        sliding-window config bounds the page at the window instead).
    sample_fn : (B, V) float32 logits -> (B,) int32 token ids; greedy
        argmax by default (deterministic — what the parity tests pin).
    prefill_chunk : max prompt tokens consumed per slot per step. 1
        recovers the legacy one-token teacher forcing (and is forced for
        the recurrent ssm/hybrid families, whose per-token state updates
        cannot batch along the sequence).
    prefill_budget : cap on total prefill tokens per step across all slots
        (None = unlimited) so prefill-heavy traffic cannot starve decoding
        slots; the oldest prefilling request always progresses.
    """

    def __init__(self, params, cfg, n_slots: int = 8, max_len: int = 256,
                 sample_fn: Optional[Callable] = None,
                 prefill_chunk: int = 8,
                 prefill_budget: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample_fn = sample_fn or _greedy
        self.chunk = max(1, int(prefill_chunk))
        if cfg.family in ("ssm", "hybrid"):
            self.chunk = 1           # recurrent state updates token by token
        self.prefill_budget = prefill_budget
        self.scheduler = SlotScheduler(n_slots)
        self.stats = ServeStats(n_slots=n_slots)

        self.caches = init_caches(cfg, n_slots, max_len, per_slot=True)
        # host-side per-slot state
        self._tokens = np.zeros((n_slots, 1), np.int32)   # last sampled token
        self._index = np.zeros((n_slots,), np.int32)      # absolute position

        # donate the cache pool: decode updates it in place instead of
        # materializing a second copy every step (2x HBM otherwise; CPU
        # ignores donation with a harmless warning)
        self._step = jax.jit(
            lambda p, b, c, i: decode_step(p, cfg, b, c, i),
            donate_argnums=(2,))
        self._prefill = jax.jit(
            lambda p, b, c, i, l: _model.prefill_chunk(p, cfg, b, c, i, l),
            donate_argnums=(2,))
        self._reset = jax.jit(_reset_slot, donate_argnums=(0,))

        # quantization-health sweep of the packed weights: per-layer clip
        # rate / scale saturation / meta modes / re-encode drift gauges,
        # once at startup (off the decode hot path)
        if obs.enabled("health"):
            with obs.span("serve.weight_health", cat="obs"):
                obs.quant_health.weight_tree_health(params)

    # -- request lifecycle -------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        """Queue a request; it is admitted when a slot frees up."""
        if len(prompt) + max_new_tokens > self.max_len \
                and not self.cfg.sliding_window:
            raise ValueError(
                f"prompt+generation {len(prompt)}+{max_new_tokens} exceeds "
                f"cache capacity {self.max_len}")
        return self.scheduler.submit(list(prompt), max_new_tokens, eos_id)

    def _admit(self) -> None:
        for req in self.scheduler.admit(self.stats.steps):
            slot = req.slot
            self.caches = self._reset(self.caches, jnp.int32(slot))
            self._index[slot] = 0

    # -- decode loop -------------------------------------------------------

    def _launch_decode(self, chunks) -> np.ndarray:
        """One-token launch for every slot. Returns (B, V) f32 logits at
        each slot's (single) position."""
        for slot, req in self.scheduler.active.items():
            if req.phase == "prefill":
                self._tokens[slot, 0] = req.prompt[req.consumed]
        with obs.span("serve.kernel.dispatch", kind="decode_step",
                      slots=self.n_slots):
            logits, self.caches = self._step(
                self.params, {"tokens": jnp.asarray(self._tokens)},
                self.caches, jnp.asarray(self._index))
            out = np.asarray(logits[:, -1]).astype(np.float32)
        return out

    def _launch_prefill(self, chunks) -> np.ndarray:
        """Mixed chunked launch: prefilling slots consume their planned
        chunk, decode slots their next token, idle / budget-starved slots
        are masked out (length 0). Returns (B, V) f32 logits at each slot's
        last valid position."""
        toks = np.zeros((self.n_slots, self.chunk), np.int32)
        lens = np.zeros((self.n_slots,), np.int32)
        for slot, req in self.scheduler.active.items():
            c = chunks.get(slot, 0)
            if c == 0:
                continue
            lens[slot] = c
            if req.phase == "prefill":
                toks[slot, :c] = req.prompt[req.consumed:req.consumed + c]
            else:
                toks[slot, 0] = self._tokens[slot, 0]
        with obs.span("serve.kernel.dispatch", kind="prefill_chunk",
                      slots=self.n_slots, tokens=int(lens.sum())):
            logits, self.caches = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, self.caches,
                jnp.asarray(self._index), jnp.asarray(lens))
            lg = np.asarray(logits).astype(np.float32)    # (B, T, V)
        return lg[np.arange(self.n_slots), np.maximum(lens - 1, 0)]

    def step(self) -> int:
        """Admit, plan per-slot chunks, run one batched launch, route
        tokens. Returns the number of requests that finished this step."""
        with obs.span("serve.step", step=self.stats.steps):
            return self._step_inner()

    def _step_inner(self) -> int:
        with obs.span("serve.admit"):
            self._admit()
        if not self.scheduler.active:
            return 0
        with obs.span("serve.plan"):
            chunks = self.scheduler.plan_chunks(self.chunk,
                                                self.prefill_budget)
        decode_only = all(c == 1 for c in chunks.values())
        phase = "decode" if decode_only else "prefill"
        t0 = time.perf_counter()
        with obs.span(f"serve.phase.{phase}",
                      slots=len(self.scheduler.active)):
            if decode_only:
                sampled_from = self._launch_decode(chunks)
            else:
                sampled_from = self._launch_prefill(chunks)
        dt = time.perf_counter() - t0
        with obs.span("serve.sample"):
            sampled = self.sample_fn(sampled_from)

        finished = 0
        first_tokens, new_prefill, new_generated = [], 0, 0
        self.stats.steps += 1
        if decode_only:
            self.stats.decode_steps += 1
            self.stats.decode_wall_s += dt
        else:
            self.stats.prefill_steps += 1
            self.stats.prefill_wall_s += dt
        for slot, req in list(self.scheduler.active.items()):
            c = chunks.get(slot, 0)
            if c == 0:
                continue                       # budget-starved: no progress
            self.stats.slot_steps += 1
            if req.phase == "prefill":
                req.consumed += c
                still_prefilling = req.consumed < len(req.prompt)
                fed = c - (0 if still_prefilling else 1)
                self.stats.prefill_tokens += fed
                new_prefill += fed
                if still_prefilling:
                    self._index[slot] += c
                    continue                   # logits discarded
                # the chunk ended on the last prompt token: its logits
                # sample the first generated token
                self.stats.generated_tokens += 1
            else:
                self.stats.generated_tokens += 1
            new_generated += 1
            tok = int(sampled[slot])
            req.output.append(tok)
            if req.first_token_step < 0:
                req.first_token_step = self.stats.steps
                first_tokens.append(req)
            self._tokens[slot, 0] = tok
            self._index[slot] += c
            if req.done:
                self.scheduler.evict(slot, self.stats.steps)
                obs.instant("serve.evict", rid=req.rid)
                finished += 1
        if obs.enabled():
            self._record_step_metrics(phase, dt, first_tokens,
                                      new_prefill, new_generated, finished)
        return finished

    def _record_step_metrics(self, phase, dt, first_tokens, new_prefill,
                             new_generated, finished) -> None:
        obs.histogram("repro_serve_step_latency_seconds",
                      "wall seconds per engine launch").observe(
            dt, phase=phase)
        obs.counter("repro_serve_steps_total",
                    "engine launches").inc(phase=phase)
        if new_prefill:
            obs.counter("repro_serve_tokens_total",
                        "tokens through the engine").inc(
                new_prefill, kind="prefill")
        if new_generated:
            obs.counter("repro_serve_tokens_total", "").inc(
                new_generated, kind="generated")
        if finished:
            obs.counter("repro_serve_requests_finished_total",
                        "requests that completed").inc(finished)
        for req in first_tokens:
            obs.histogram("repro_serve_ttft_steps",
                          "engine steps from admission to first token",
                          buckets=_TTFT_BUCKETS).observe(req.ttft_steps)
        obs.gauge("repro_serve_queue_depth",
                  "requests waiting for a slot").set(
            len(self.scheduler.queue))
        obs.gauge("repro_serve_active_slots",
                  "slots holding a running request").set(
            len(self.scheduler.active))
        obs.gauge("repro_serve_occupancy",
                  "mean fraction of slots progressing per step").set(
            self.stats.occupancy)

    def run(self) -> List[Request]:
        """Step until queue and slots drain. Returns the requests that
        finished during *this* drain, in submission order."""
        already_done = len(self.scheduler.finished)
        t0 = time.perf_counter()
        with obs.span("serve.run", slots=self.n_slots):
            while self.scheduler.has_work:
                self.step()
        self.stats.wall_s += time.perf_counter() - t0
        obs.autodump()          # metrics.jsonl + trace.json -> REPRO_OBS_DIR
        return sorted(self.scheduler.finished[already_done:],
                      key=lambda r: r.rid)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int,
                 eos_id: Optional[int] = None) -> List[List[int]]:
        """Batch convenience: submit every prompt, drain, return outputs."""
        reqs = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        self.run()
        return [r.output for r in reqs]

    # -- accounting --------------------------------------------------------

    def mean_ttft_steps(self) -> float:
        """Mean steps from admission to first sampled token over every
        request that produced output (chunked prefill drives this down from
        ~prompt_len to ~ceil(prompt_len / prefill_chunk))."""
        ttfts = [r.ttft_steps for r in self.scheduler.finished
                 if r.ttft_steps >= 0]
        ttfts += [r.ttft_steps for r in self.scheduler.active.values()
                  if r.ttft_steps >= 0]
        return float(np.mean(ttfts)) if ttfts else 0.0

    def weight_bytes(self) -> int:
        return tree_nbytes(self.params)

    def kv_bytes(self) -> int:
        return tree_nbytes(self.caches)
