"""Batched autoregressive serving engine over packed M2XFP weight streams.

The engine owns:
  * a packed parameter tree (``repro.serve.prequant`` / checkpoint load) —
    every GEMM weight resident in HBM as u8 code/scale/meta streams,
    4.5 bits/element, decoded inline by the quantized matmul (Pallas kernel
    on TPU, XLA mirror on CPU — see repro.models.quant);
  * a paged KV cache: ``init_caches(..., per_slot=True)`` — batch row b is
    request slot b, a fixed-size page of the cache pool with its own
    position track, admitted/evicted independently (continuous batching);
    with ``cfg.kv_quant == 'm2xfp'`` pages hold packed Sg-EM streams;
  * a host-side ``SlotScheduler`` deciding which request occupies which
    slot each step.

Every decode step runs ONE jitted ``decode_step`` over all slots with a
(B,) per-slot position vector. Prompts are teacher-forced through the same
decode step (one prompt token consumed per step), so a newly admitted
request prefils while its neighbours keep generating — no batch-wide stall.
Slots whose request finished keep ticking on a dummy token until the
scheduler refills them; admit-time reset invalidates the slot's position
track (which masks every stale KV entry) and re-initializes recurrent
state, so no state leaks between requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_caches

from .scheduler import Request, SlotScheduler

__all__ = ["ServeEngine", "ServeStats", "tree_nbytes"]


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf (what the tree keeps resident)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclasses.dataclass
class ServeStats:
    n_slots: int = 1
    steps: int = 0                 # decode steps launched
    slot_steps: int = 0            # sum over steps of active slots
    prefill_tokens: int = 0        # prompt tokens teacher-forced
    generated_tokens: int = 0      # tokens sampled and returned
    wall_s: float = 0.0

    @property
    def tokens_per_sec(self) -> float:
        total = self.prefill_tokens + self.generated_tokens
        return total / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per step."""
        if not self.steps:
            return 0.0
        return self.slot_steps / (self.steps * self.n_slots)


def _greedy(logits: np.ndarray) -> np.ndarray:
    """(B, V) -> (B,) argmax token ids."""
    return np.argmax(logits, axis=-1).astype(np.int32)


def _reset_slot(caches: dict, slot: jax.Array) -> dict:
    """Return ``caches`` with one slot's rows back in their init state.

    Every cache leaf is layer-stacked with the slot (batch) axis second.
    Attention K/V pages need no scrub — setting the slot's position track
    to -1 masks every stale entry (``attention_decode``'s valid test), so
    only the position rows and the recurrent-state rows are written.
    ``m`` is the mlstm/slstm running log-max, initialized to -1e30."""
    def fix(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        if name == "pos":
            return leaf.at[:, slot].set(-1)
        if any(k in ("mlstm", "slstm", "mamba") for k in keys):
            fill = -1e30 if name == "m" else 0.0
            return leaf.at[:, slot].set(jnp.asarray(fill, leaf.dtype))
        return leaf                        # K/V pages: masked via pos
    return jax.tree_util.tree_map_with_path(fix, caches)


class ServeEngine:
    """Continuous-batching decode engine. See module docstring.

    Parameters
    ----------
    params : packed parameter tree (``prequantize_params`` output) — or a
        dense tree if ``cfg.quant != 'serve'`` (useful for A/B parity runs).
    cfg : ModelConfig, normally with ``quant='serve'``.
    n_slots : batch width = number of concurrently served requests.
    max_len : cache capacity per slot (prompt + generated tokens; a
        sliding-window config bounds the page at the window instead).
    sample_fn : (B, V) float32 logits -> (B,) int32 token ids; greedy
        argmax by default (deterministic — what the parity tests pin).
    """

    def __init__(self, params, cfg, n_slots: int = 8, max_len: int = 256,
                 sample_fn: Optional[Callable] = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample_fn = sample_fn or _greedy
        self.scheduler = SlotScheduler(n_slots)
        self.stats = ServeStats(n_slots=n_slots)

        self.caches = init_caches(cfg, n_slots, max_len, per_slot=True)
        # host-side per-slot state
        self._tokens = np.zeros((n_slots, 1), np.int32)   # next input token
        self._index = np.zeros((n_slots,), np.int32)      # absolute position

        # donate the cache pool: decode updates it in place instead of
        # materializing a second copy every step (2x HBM otherwise; CPU
        # ignores donation with a harmless warning)
        self._step = jax.jit(
            lambda p, b, c, i: decode_step(p, cfg, b, c, i),
            donate_argnums=(2,))
        self._reset = jax.jit(_reset_slot, donate_argnums=(0,))

    # -- request lifecycle -------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> Request:
        """Queue a request; it is admitted when a slot frees up."""
        if len(prompt) + max_new_tokens > self.max_len \
                and not self.cfg.sliding_window:
            raise ValueError(
                f"prompt+generation {len(prompt)}+{max_new_tokens} exceeds "
                f"cache capacity {self.max_len}")
        return self.scheduler.submit(list(prompt), max_new_tokens, eos_id)

    def _admit(self) -> None:
        for req in self.scheduler.admit(self.stats.steps):
            slot = req.slot
            self.caches = self._reset(self.caches, jnp.int32(slot))
            self._index[slot] = 0
            self._tokens[slot, 0] = req.prompt[0]

    # -- decode loop -------------------------------------------------------

    def step(self) -> int:
        """Admit, run one batched decode step, route tokens. Returns the
        number of requests that finished this step."""
        self._admit()
        if not self.scheduler.active:
            return 0
        logits, self.caches = self._step(
            self.params, {"tokens": jnp.asarray(self._tokens)}, self.caches,
            jnp.asarray(self._index))
        sampled = self.sample_fn(
            np.asarray(logits[:, -1]).astype(np.float32))

        finished = 0
        self.stats.steps += 1
        self.stats.slot_steps += len(self.scheduler.active)
        for slot, req in list(self.scheduler.active.items()):
            consumed = self._index[slot] + 1       # tokens fed so far
            if consumed < len(req.prompt):
                # still prefilling: teacher-force the next prompt token
                # (the emitted token is discarded)
                self._tokens[slot, 0] = req.prompt[consumed]
                self.stats.prefill_tokens += 1
            else:
                tok = int(sampled[slot])
                req.output.append(tok)
                self._tokens[slot, 0] = tok
                self.stats.generated_tokens += 1
            self._index[slot] += 1
            if req.done:
                self.scheduler.evict(slot, self.stats.steps)
                finished += 1
        return finished

    def run(self) -> List[Request]:
        """Step until queue and slots drain. Returns the requests that
        finished during *this* drain, in submission order."""
        already_done = len(self.scheduler.finished)
        t0 = time.perf_counter()
        while self.scheduler.has_work:
            self.step()
        self.stats.wall_s += time.perf_counter() - t0
        return sorted(self.scheduler.finished[already_done:],
                      key=lambda r: r.rid)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int,
                 eos_id: Optional[int] = None) -> List[List[int]]:
        """Batch convenience: submit every prompt, drain, return outputs."""
        reqs = [self.submit(p, max_new_tokens, eos_id) for p in prompts]
        self.run()
        return [r.output for r in reqs]

    # -- accounting --------------------------------------------------------

    def weight_bytes(self) -> int:
        return tree_nbytes(self.params)

    def kv_bytes(self) -> int:
        return tree_nbytes(self.caches)
