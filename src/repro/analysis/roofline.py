"""Three-term roofline model over the dry-run artifacts (TPU v5e target).

    compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s)    [bf16 MXU]
    memory     = HLO_bytes        / (chips * 819e9  B/s)       [HBM]
    collective = collective_bytes / (chips * 50e9   B/s)       [ICI/link]

HLO_* figures are global (= per-device loop-aware static analysis x chips;
see hlo.py for why cost_analysis alone is insufficient). The bound time is
max(terms) under perfect overlap; the dominant term is the optimization
target of the perf loop (EXPERIMENTS.md §Perf).

MODEL_FLOPS uses the 6·N·D training convention (2·N·D for forward-only
serving; N = active params for MoE); the ratio MODEL_FLOPS / HLO_FLOPs
exposes remat/redundant compute (ratio < 1 when the compiled program does
extra work, > 1 only if the analyzer missed compute).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (~1 link budget per chip)

__all__ = ["RooflineTerms", "roofline", "model_flops",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    hlo_flops: float             # global
    hlo_bytes: float             # global
    collective_bytes: float      # global
    model_flops: float
    dominant: str = ""
    useful_ratio: float = 0.0    # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float = 0.0

    def finalize(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.hlo_flops
                             if self.hlo_flops else 0.0)
        # fraction of ideal: useful-FLOPs time vs the bound time
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(terms.values())
        self.roofline_fraction = ideal / bound if bound > 0 else 0.0
        return self

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(per_device_flops: float, per_device_bytes: float,
             per_device_coll_bytes: float, chips: int,
             model_flops_: float) -> RooflineTerms:
    gf = per_device_flops * chips
    gb = per_device_bytes * chips
    gc = per_device_coll_bytes * chips
    return RooflineTerms(
        compute_s=gf / (chips * PEAK_FLOPS),
        memory_s=gb / (chips * HBM_BW),
        collective_s=gc / (chips * LINK_BW),
        chips=chips, hlo_flops=gf, hlo_bytes=gb, collective_bytes=gc,
        model_flops=model_flops_,
    ).finalize()


def model_flops(cfg, shape: dict) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), N = active params."""
    n = cfg.active_params
    if shape["kind"] == "train":
        return 6.0 * n * shape["batch"] * shape["seq"]
    if shape["kind"] == "prefill":
        return 2.0 * n * shape["batch"] * shape["seq"]
    return 2.0 * n * shape["batch"]          # decode: one token / sequence
