"""Loop-aware static analysis of partitioned HLO text.

Why this exists: ``compiled.cost_analysis()`` visits a ``while`` body ONCE,
but every ``lax.scan`` (layers, pipeline microbatches, SSD chunks) compiles
to a while loop — so both FLOPs and collective traffic would be undercounted
by the trip count (56x for mixtral's layer scan). This module parses the
post-SPMD-partitioner HLO, recovers loop trip counts from the loop
conditions, and propagates multipliers through the call graph.

Counted per executed instruction (x its computation's multiplier):
  * FLOPs: dot ops — 2 * prod(output dims) * prod(contracting dims)
    (dots inside fusion bodies included). Elementwise FLOPs are ignored;
    on these models they are <1% of dot FLOPs and rooflines conventionally
    use MAC FLOPs.
  * HBM bytes: sum of operand + result bytes of *top-level* instructions
    (fusion interiors excluded — they live in registers/VMEM). This is the
    standard post-fusion traffic proxy.
  * Collective wire bytes (per device), ring-algorithm estimates:
      all-gather          out * (G-1)/G
      reduce-scatter      out * (G-1)         (input traverses the ring)
      all-reduce          2 * out * (G-1)/G
      all-to-all          out * (G-1)/G
      collective-permute  out
    with G = replica-group size parsed from the instruction.

Shapes in the partitioned module are per-device; multiply by chip count for
global figures (the roofline formulas divide it straight back out).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HloAnalysis", "analyze_hlo", "parse_bytes_of_shape"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# shape group: lazy up to the first ``<op>(`` token — handles tuple shapes
# containing /*index=N*/ comments (no parens appear inside shape tokens)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=(%[\w.\-]+).*?body=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=(%[\w.\-]+)")
_COND_CALLS_RE = re.compile(
    r"(?:true_computation|false_computation|branch_computations)="
    r"\{?(%[\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_bytes_of_shape(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    line: str


def _parse_computations(text: str) -> dict:
    comps: dict[str, list[_Instr]] = {}
    current = None
    for raw in text.splitlines():
        if current is None:
            m = _COMP_HDR.match(raw)
            if m and "{" in raw:
                current = m.group(1)
                comps[current] = []
            continue
        if raw.strip() == "}" or raw.rstrip() == "}":
            current = None
            continue
        m = _DEF_RE.match(raw)
        if m:
            comps[current].append(
                _Instr(m.group(1), m.group(2), m.group(3), raw))
    return comps


def _entry_name(text: str) -> str:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                return m.group(1)
    # fall back: first computation
    return next(iter(_parse_computations(text)))


def _trip_count(cond_instrs: list[_Instr]) -> int:
    """Loop bound from the condition computation: the constant in the
    iv < N compare (jax scans emit static bounds)."""
    consts = []
    for ins in cond_instrs:
        consts += [int(c) for c in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def _dot_flops(ins: _Instr, shapes: dict) -> float:
    out_elems = 1
    for d in _shape_dims(ins.shape):
        out_elems *= d
    m = _LHS_CONTRACT_RE.search(ins.line)
    contract = 1
    if m and m.group(1):
        # operands: dot(%a, %b)
        ops = re.findall(r"\((%[\w.\-]+),\s*(%[\w.\-]+)", ins.line)
        if ops:
            lhs_shape = shapes.get(ops[0][0], "")
            dims = _shape_dims(lhs_shape)
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


def _scaled_bytes(shape_str: str, trips: int) -> float:
    """Bytes of a tensor, de-rated when it is a loop-stacked buffer: inside
    a body with trip count T, an operand whose LEADING dim equals T is the
    scan xs/ys stack — each iteration only touches the 1/T slice (XLA
    aliases the update in place on TPU)."""
    b = parse_bytes_of_shape(shape_str)
    if trips > 1:
        dims = _shape_dims(shape_str)
        if dims and dims[0] == trips:
            return b / trips
    return b


def _instr_bytes(ins: _Instr, shapes: dict, trips: int = 1) -> float:
    """Operand + result bytes of a top-level instruction (loop-aware).

    dynamic-update-slice aliases its big operand in place: only the update
    slice moves (read update + write slice). dynamic-slice likewise reads
    only the slice. Charging full buffers would overcount scan machinery
    by the trip count."""
    operands = re.findall(r"(%[\w.\-]+)", ins.line.split("(", 1)[1])
    if ins.op == "dynamic-update-slice":
        upd = operands[1] if len(operands) > 1 else None
        upd_bytes = parse_bytes_of_shape(shapes.get(upd, "")) if upd else 0.0
        return 2.0 * upd_bytes
    if ins.op == "dynamic-slice":
        return 2.0 * parse_bytes_of_shape(ins.shape)
    total = _scaled_bytes(ins.shape, trips)
    for name in operands:
        if name in shapes:
            total += _scaled_bytes(shapes[name], trips)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


def _collective_wire_bytes(kind: str, out_bytes: float, g: int) -> float:
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return out_bytes                      # collective-permute


@dataclasses.dataclass
class HloAnalysis:
    flops: float                          # per-device dot FLOPs (loop-aware)
    hbm_bytes: float                      # per-device traffic proxy
    collective_bytes: float               # per-device wire bytes
    per_kind_bytes: dict
    per_kind_count: dict
    loop_trips: dict                      # body name -> trip count
    f32_mirror_bytes: float = 0.0         # CPU-backend artifact (see below)


def analyze_hlo(text: str, default_group: int = 2) -> HloAnalysis:
    comps = _parse_computations(text)
    entry = _entry_name(text)

    # shape tables per computation (params + defs)
    shape_tables = {}
    for cname, instrs in comps.items():
        tbl = {}
        for ins in instrs:
            tbl[ins.name] = ins.shape
        shape_tables[cname] = tbl

    # call graph with multipliers
    mult: dict[str, float] = defaultdict(float)
    fusion_of: dict[str, str] = {}

    def visit(cname: str, m: float):
        if cname not in comps:
            return
        mult[cname] += m
        for ins in comps[cname]:
            w = _WHILE_RE.search(ins.line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(cond, m * (trips + 1))
                visit(body, m * trips)
                continue
            c = _CALLS_RE.search(ins.line)
            if c and ins.op == "fusion":
                fusion_of[c.group(1)] = cname
                continue                  # fused body: flops only, below
            for pat in (_CALLS_RE, _TO_APPLY_RE):
                cc = pat.search(ins.line)
                if cc and ins.op not in ("fusion",):
                    visit(cc.group(1), m)
            cond_c = _COND_CALLS_RE.search(ins.line)
            if cond_c:
                for sub in re.findall(r"%[\w.\-]+", cond_c.group(1)):
                    visit(sub, m)

    visit(entry, 1.0)

    # fusions whose root is a dynamic-update-slice alias their big operand
    # in place — identify them so only the incremental bytes are charged
    dus_rooted = set()
    for cname, instrs in comps.items():
        if instrs and instrs[-1].op == "dynamic-update-slice":
            dus_rooted.add(cname)

    flops = 0.0
    hbm = 0.0
    coll_bytes = defaultdict(float)
    coll_count = defaultdict(int)
    trips_out = {}

    # body name -> its own trip count (for stacked-operand de-rating)
    body_trips = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            w = _WHILE_RE.search(ins.line)
            if w:
                body_trips[w.group(2)] = _trip_count(comps.get(w.group(1), []))

    for cname, m in list(mult.items()):
        if m <= 0 or cname not in comps:
            continue
        tbl = shape_tables[cname]
        own_trips = body_trips.get(cname, 1)
        for ins in comps[cname]:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, tbl)
            if ins.op in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "while", "conditional",
                          "call", "after-all"):
                # control flow / aliasing ops move no HBM bytes themselves;
                # their bodies are traversed with their own multipliers
                continue
            b = _instr_bytes(ins, tbl, own_trips)
            if ins.op == "fusion":
                c = _CALLS_RE.search(ins.line)
                if c and c.group(1) in dus_rooted:
                    # in-place update fusion: subtract the aliased pair
                    # (full buffer counted once as operand, once as output)
                    big = _scaled_bytes(ins.shape, own_trips)
                    b = max(b - 2.0 * big, big * 0.01)
            hbm += m * b
            kind = next((k for k in _COLLECTIVES if ins.op.startswith(k)), None)
            if kind and not ins.op.endswith("-done"):
                g = _group_size(ins.line, default_group)
                coll_bytes[kind] += m * _collective_wire_bytes(
                    kind, parse_bytes_of_shape(ins.shape), g)
                coll_count[kind] += int(m)

    # dots inside fusion bodies (flops only; bytes already at fusion level)
    for fname, caller in fusion_of.items():
        m = mult.get(caller, 0.0)
        if m <= 0 or fname not in comps:
            continue
        tbl = shape_tables[fname]
        for ins in comps[fname]:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, tbl)

    # record loop trip counts for reporting
    for cname, instrs in comps.items():
        for ins in instrs:
            w = _WHILE_RE.search(ins.line)
            if w:
                trips_out[w.group(2)] = _trip_count(comps.get(w.group(1), []))

    # CPU-backend artifact: XLA CPU legalizes bf16 dots by upcasting
    # operands to f32; convert(slice(X)) -> slice(convert(X))
    # canonicalization then hoists FULL f32 mirrors of bf16 loop buffers
    # (e.g. the whole KV cache) out of scans. A TPU backend feeds bf16
    # straight into the MXU, so these mirrors don't exist there. We sum
    # large (>= 64 MiB) f32 convert-from-bf16 outputs so the dry-run can
    # report a TPU-representative corrected peak.
    mirror = 0.0
    conv_re = re.compile(r"=\s*(f32\[[0-9,]+\][^ ]*)\s+convert\(")
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op != "convert":
                continue
            m = conv_re.search(ins.line)
            if not m:
                continue
            sz = parse_bytes_of_shape(m.group(1))
            if sz >= 64 * 2 ** 20:
                mirror += sz

    return HloAnalysis(
        flops=flops, hbm_bytes=hbm,
        collective_bytes=sum(coll_bytes.values()),
        per_kind_bytes=dict(coll_bytes), per_kind_count=dict(coll_count),
        loop_trips=trips_out, f32_mirror_bytes=mirror)
