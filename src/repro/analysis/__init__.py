# Dry-run analysis: HLO collective extraction + three-term roofline model.
