"""Baseline file handling: grandfather existing violations, block new ones.

The committed ``lint-baseline.json`` holds the violations the repo has
accepted (each with a justification). Identity is (path, rule, message) —
line numbers are deliberately excluded so unrelated edits don't churn the
file — with a ``count`` per identity for repeated hits.

``diff_against_baseline`` splits live violations into *new* (not covered —
these fail the build) and reports *stale* entries (baselined violations
that no longer occur — these should be deleted so the baseline only ever
shrinks).
"""
from __future__ import annotations

import collections
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from .core import Violation

__all__ = ["BASELINE_NAME", "baseline_path", "load_baseline",
           "save_baseline", "diff_against_baseline"]

BASELINE_NAME = "lint-baseline.json"
_VERSION = 1


def baseline_path(root: str) -> str:
    return os.path.join(root, BASELINE_NAME)


def load_baseline(path: str) -> List[dict]:
    """Entries of a baseline file ([] when the file doesn't exist)."""
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("version") != _VERSION:
        raise ValueError(
            f"{path}: not a reprolint baseline (expected a JSON object "
            f"with version={_VERSION})")
    entries = data.get("entries", [])
    for e in entries:
        for key in ("path", "rule", "message"):
            if key not in e:
                raise ValueError(f"{path}: baseline entry missing {key!r}: "
                                 f"{e}")
        e.setdefault("count", 1)
    return entries


def save_baseline(path: str, violations: Sequence[Violation],
                  justification: str = "grandfathered by --update-baseline"
                  ) -> List[dict]:
    """Write the current violations as the new baseline (sorted, counted)."""
    counts: Dict[Tuple[str, str, str], int] = collections.Counter(
        v.ident() for v in violations)
    entries = [
        {"path": p, "rule": r, "message": m, "count": c,
         "justification": justification}
        for (p, r, m), c in sorted(counts.items())
    ]
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": _VERSION, "entries": entries}, f, indent=1)
        f.write("\n")
    return entries


def diff_against_baseline(violations: Sequence[Violation],
                          entries: Sequence[dict]
                          ) -> Tuple[List[Violation], List[dict]]:
    """(new_violations, stale_entries).

    Each baseline entry absorbs up to ``count`` live violations with the
    same (path, rule, message); the rest are new. Entries with leftover
    capacity are stale (the violation was fixed — delete the entry)."""
    budget: Dict[Tuple[str, str, str], int] = {}
    for e in entries:
        key = (e["path"], e["rule"], e["message"])
        budget[key] = budget.get(key, 0) + int(e.get("count", 1))
    new = []
    for v in sorted(violations, key=lambda v: (v.path, v.line, v.col)):
        key = v.ident()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
        else:
            new.append(v)
    stale = []
    for e in entries:
        key = (e["path"], e["rule"], e["message"])
        if budget.get(key, 0) > 0:
            stale.append(e)
            budget[key] = 0          # report an identity once
    return new, stale
