"""reprolint command line: the logic behind ``scripts/lint.py``.

Exit codes: 0 = clean modulo the committed baseline; 1 = new violations
(or, with ``--check-baseline``, stale baseline entries); 2 = usage error.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

from . import baseline as bl
from .core import (DEFAULT_TARGETS, RULES, _load_builtin_rules, lint_paths,
                   repo_root)
from .report import render_json, render_summary, render_text

__all__ = ["main", "build_parser"]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="lint.py",
        description="reprolint: enforce the repo's quantization, jit-safety "
                    "and Pallas-kernel invariants")
    p.add_argument("paths", nargs="*",
                   help=f"files/directories to lint (default: "
                        f"{', '.join(DEFAULT_TARGETS)})")
    p.add_argument("--json", action="store_true",
                   help="emit the machine-readable JSON report")
    p.add_argument("--rule", action="append", dest="rules", metavar="RULE",
                   help="run only this rule (repeatable)")
    p.add_argument("--baseline", metavar="PATH",
                   help="baseline file (default: <repo>/lint-baseline.json)")
    p.add_argument("--no-baseline", action="store_true",
                   help="report every violation, ignoring the baseline")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline to grandfather the current "
                        "violations")
    p.add_argument("--check-baseline", action="store_true",
                   help="CI mode: also fail when the baseline holds stale "
                        "entries for violations that no longer exist")
    p.add_argument("--list-rules", action="store_true",
                   help="print the registered rules and exit")
    p.add_argument("--list-env", action="store_true",
                   help="print the REPRO_* env-flag registry as a markdown "
                        "table and exit")
    return p


def _list_rules() -> str:
    _load_builtin_rules()
    width = max(len(n) for n in RULES)
    return "\n".join(
        f"{name:<{width}}  [{rule.severity}] {rule.description}"
        for name, rule in sorted(RULES.items()))


def _list_env() -> str:
    try:
        from repro.core import envflags
    except ImportError:
        # importing the repro.core package pulls in jax; envflags itself is
        # stdlib-only, so in bare environments (the CI lint job) load it
        # directly by path instead
        import importlib.util
        path = os.path.join(repo_root(), "src", "repro", "core",
                            "envflags.py")
        spec = importlib.util.spec_from_file_location("_repro_envflags", path)
        envflags = importlib.util.module_from_spec(spec)
        sys.modules[spec.name] = envflags   # dataclasses resolves __module__
        spec.loader.exec_module(envflags)
    return envflags.markdown_table()


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0
    if args.list_env:
        print(_list_env())
        return 0

    root = repo_root()
    if args.paths:
        targets = [p if os.path.isabs(p) else os.path.join(os.getcwd(), p)
                   for p in args.paths]
    else:
        targets = [os.path.join(root, t) for t in DEFAULT_TARGETS]
    targets = [t for t in targets if os.path.exists(t)]
    only = frozenset(args.rules) if args.rules else None
    if only:
        _load_builtin_rules()
        unknown = only - set(RULES)
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                  f"see --list-rules", file=sys.stderr)
            return 2

    violations = lint_paths(targets, root=root, only=only)

    bpath = args.baseline or bl.baseline_path(root)
    if args.update_baseline:
        entries = bl.save_baseline(bpath, violations)
        print(f"wrote {bpath}: {len(entries)} baselined identit"
              f"{'y' if len(entries) == 1 else 'ies'} covering "
              f"{len(violations)} violation(s)")
        return 0

    stale: List[dict] = []
    if not args.no_baseline:
        entries = bl.load_baseline(bpath)
        violations, stale = bl.diff_against_baseline(violations, entries)

    if args.json:
        print(render_json(violations, stale))
    else:
        text = render_text(violations)
        if text:
            print(text)
        print(render_summary(violations, stale))

    if violations:
        return 1
    if args.check_baseline and stale:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
