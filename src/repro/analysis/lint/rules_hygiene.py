"""General hygiene rules: bare excepts, mutable default arguments, and
missing ``__all__`` exports on public ``repro.*`` package surfaces.

* ``bare-except`` — ``except:`` swallows KeyboardInterrupt/SystemExit and
  masks real faults as recoverable; the fault-tolerant serving path
  depends on exception *types* (TransientStepError vs everything else) to
  decide retry-vs-fail, so a blanket handler can turn a real fault into a
  silent retry loop. Catch a concrete type, or ``Exception`` with a
  justifying comment.
* ``mutable-default`` — a ``def f(x=[])`` default is shared across calls;
  with config/stream dicts that means cross-request state bleed in the
  engine.
* ``missing-all`` — a package ``__init__.py`` under ``src/repro`` that
  re-exports names without declaring ``__all__`` has no machine-readable
  public surface; docs snippets and ``from repro.x import *`` users see
  whatever happens to be imported, including transitive modules.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import ModuleContext, Rule, Violation, register_rule

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CALLS = ("list", "dict", "set", "bytearray", "defaultdict",
                  "OrderedDict", "Counter", "deque")


@register_rule
class BareExceptRule(Rule):
    name = "bare-except"
    description = "bare `except:` handlers (mask SystemExit and fault types)"

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                yield ctx.violation(
                    self, node,
                    "bare `except:` catches KeyboardInterrupt/SystemExit "
                    "and erases the exception type the fault-handling "
                    "paths dispatch on; catch a concrete exception class")


@register_rule
class MutableDefaultRule(Rule):
    name = "mutable-default"
    description = "mutable default argument values shared across calls"

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                continue
            defaults = list(fn.args.defaults) + [
                d for d in fn.args.kw_defaults if d is not None]
            for d in defaults:
                bad = isinstance(d, _MUTABLE_LITERALS)
                if isinstance(d, ast.Call) and isinstance(d.func, ast.Name) \
                        and d.func.id in _MUTABLE_CALLS:
                    bad = True
                if bad:
                    name = getattr(fn, "name", "<lambda>")
                    yield ctx.violation(
                        self, d,
                        f"mutable default argument in '{name}' is shared "
                        f"across calls; default to None and construct "
                        f"inside the body")


@register_rule
class MissingAllRule(Rule):
    name = "missing-all"
    severity = "warning"
    description = ("public repro.* package __init__ re-exports without an "
                   "__all__ declaration")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        rel = ctx.relpath.replace("\\", "/")
        if not (rel.startswith("src/repro/") and rel.endswith("__init__.py")):
            return
        public = []
        has_all = False
        for node in ctx.tree.body:
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Name):
                        if t.id == "__all__":
                            has_all = True
                        elif not t.id.startswith("_"):
                            public.append(t.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                if not node.name.startswith("_"):
                    public.append(node.name)
            elif isinstance(node, ast.ImportFrom):
                public.extend(a.asname or a.name for a in node.names
                              if not (a.asname or a.name).startswith("_")
                              and a.name != "*")
        if public and not has_all:
            yield Violation(
                self.name, ctx.relpath, 1, 1,
                f"package __init__ exposes {len(public)} public name(s) "
                f"but declares no __all__; declare the intended public "
                f"surface", self.severity)
