"""Reporters: human-readable text and machine-readable JSON.

Both group by rule so a CI log shows at a glance which invariant family
regressed; the JSON form is stable (sorted, versioned) for tooling.
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Sequence

from .core import RULES, Violation

__all__ = ["rule_counts", "render_text", "render_json", "render_summary"]


def rule_counts(violations: Sequence[Violation]) -> Dict[str, int]:
    counts: Dict[str, int] = collections.Counter(v.rule for v in violations)
    return dict(sorted(counts.items()))


def render_text(violations: Sequence[Violation]) -> str:
    lines = [v.format() for v in
             sorted(violations, key=lambda v: (v.path, v.line, v.col, v.rule))]
    return "\n".join(lines)


def render_summary(violations: Sequence[Violation],
                   stale: Sequence[dict] = ()) -> str:
    """Per-rule count table, e.g. for the tail of a CI log."""
    counts = rule_counts(violations)
    if not counts and not stale:
        return "reprolint: clean (0 violations)"
    lines = []
    if counts:
        width = max(len(r) for r in counts)
        lines.append(f"reprolint: {sum(counts.values())} violation(s) "
                     f"across {len(counts)} rule(s):")
        for rule, n in counts.items():
            lines.append(f"  {rule:<{width}}  {n}")
    for e in stale:
        lines.append(f"  stale baseline entry: {e['path']}: [{e['rule']}] "
                     f"{e['message']} (fixed — remove it from the baseline)")
    return "\n".join(lines)


def render_json(violations: Sequence[Violation],
                stale: Sequence[dict] = ()) -> str:
    payload = {
        "version": 1,
        "counts": rule_counts(violations),
        "violations": [
            {"rule": v.rule, "path": v.path, "line": v.line, "col": v.col,
             "severity": v.severity, "message": v.message}
            for v in sorted(violations,
                            key=lambda v: (v.path, v.line, v.col, v.rule))
        ],
        "stale_baseline_entries": list(stale),
        "rules": {name: {"severity": rule.severity,
                         "description": rule.description}
                  for name, rule in sorted(RULES.items())},
    }
    return json.dumps(payload, indent=1)
