"""codec-contract: static checks over ``Codec(...)`` registrations.

The codec registry (``repro.core.codecs``) is the single format authority
for the whole stack, so a malformed entry poisons every layer at once.
What can be checked without running code (per the OCP Microscaling spec
and this repo's stream conventions):

* the mandatory surface: ``name``, ``group``, ``ebw``, and both fake-quant
  hooks;
* path pairing — a packed serving path needs *both* ``encode`` and
  ``decode``; a packed KV path needs ``kv_encode`` + ``kv_decode`` +
  ``kv_spec``; a fused ``kernel`` hook is meaningless without a packed
  path;
* literal sanity — ``group`` ∈ {16, 32} (the nibble/meta packing
  constants), ``scale_kind`` ∈ {e8m0, e4m3, f16};
* E8M0 telemetry bounds — a *packed* e8m0 codec must declare
  ``scale_sat_bounds`` and, when literal, they must be (1, 254): the
  encoders clamp exponents to [-126, 127], byte 0 never occurs and byte
  255 is reserved/NaN;
* EBW consistency — when ``ebw`` and ``group`` are numeric literals the
  claimed bits/element must equal 4 (nibble code) + 8/group (one scale
  byte per group) + 2/8 per element of 2-bit subgroup metadata when
  ``has_meta=True``. Entries computed via ``format_ebw(...)`` are checked
  at runtime by the EBW tests instead.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from .core import ModuleContext, Rule, Violation, dotted_name, register_rule

_REQUIRED = ("name", "group", "ebw", "fake_quant_weight", "fake_quant_act")
_SCALE_KINDS = ("e8m0", "e4m3", "f16")
_GROUPS = (16, 32)


def _literal(kw_map, key):
    node = kw_map.get(key)
    if isinstance(node, ast.Constant):
        return node.value
    return None


def _tuple_literal(kw_map, key) -> Optional[tuple]:
    node = kw_map.get(key)
    if isinstance(node, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) for e in node.elts):
        return tuple(e.value for e in node.elts)
    return None


@register_rule
class CodecContractRule(Rule):
    name = "codec-contract"
    description = ("Codec(...) registrations missing required surface or "
                   "with metadata inconsistent with the packing constants")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted_name(node.func) not in ("Codec", "codecs.Codec"):
                continue
            if not node.keywords:
                continue                      # positional construction: skip
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            yield from self._check_codec(ctx, node, kw)

    def _check_codec(self, ctx, node, kw) -> Iterator[Violation]:
        cname = _literal(kw, "name") or "<codec>"
        missing = [k for k in _REQUIRED if k not in kw]
        if missing:
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: missing required field(s) "
                f"{', '.join(missing)} (every codec must declare name, "
                f"group, ebw and both fake-quant hooks)")
        have_enc, have_dec = "encode" in kw, "decode" in kw
        if have_enc != have_dec:
            got, want = ("encode", "decode") if have_enc else ("decode",
                                                               "encode")
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: {got} given without {want} — the packed "
                f"serving path needs the exact inverse pair")
        kv = [k for k in ("kv_encode", "kv_decode", "kv_spec") if k in kw]
        if kv and len(kv) != 3:
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: partial KV path ({', '.join(kv)}); a "
                f"packed KV cache needs kv_encode + kv_decode + kv_spec")
        if "kernel" in kw and not have_enc:
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: fused kernel hook without a packed "
                f"encode/decode path — nothing can feed it packed streams")
        group = _literal(kw, "group")
        if group is not None and group not in _GROUPS:
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: group={group} but the nibble/meta "
                f"packing constants support groups {_GROUPS}")
        skind = _literal(kw, "scale_kind")
        if skind is not None and skind not in _SCALE_KINDS:
            yield ctx.violation(
                self, node,
                f"codec {cname!r}: scale_kind={skind!r} not in "
                f"{_SCALE_KINDS}")
        bounds = _tuple_literal(kw, "scale_sat_bounds")
        if skind == "e8m0" and have_enc:
            if "scale_sat_bounds" not in kw:
                yield ctx.violation(
                    self, node,
                    f"codec {cname!r}: packed e8m0 codec without "
                    f"scale_sat_bounds — the health telemetry cannot "
                    f"detect scale saturation")
            elif bounds is not None and bounds != (1, 254):
                yield ctx.violation(
                    self, node,
                    f"codec {cname!r}: e8m0 scale_sat_bounds={bounds} but "
                    f"the encoders clamp exponents to [-126, 127] (bytes "
                    f"[1, 254]; 0 never occurs, 255 is reserved/NaN)")
        ebw = _literal(kw, "ebw")
        if isinstance(ebw, (int, float)) and isinstance(group, int) \
                and group > 0:
            meta = _literal(kw, "has_meta")
            expect = 4.0 + 8.0 / group + (2.0 / 8.0 if meta is True else 0.0)
            if abs(float(ebw) - expect) > 1e-9:
                yield ctx.violation(
                    self, node,
                    f"codec {cname!r}: literal ebw={ebw} inconsistent with "
                    f"its streams — 4-bit nibbles + one scale byte per "
                    f"{group}-group"
                    + (" + 2-bit subgroup metadata" if meta is True else "")
                    + f" = {expect} bits/element")
