"""kernel-contract: Pallas launch invariants.

Three hazards this repo has actually to guard against (docs/kernels.md):

* **Accumulation width** — the fp4 dequant-GEMMs feed the MXU with bf16
  operands; without ``preferred_element_type=jnp.float32`` the dot
  accumulates in bf16 and the K-loop partial sums drift (the exactness
  proofs in tests/test_kernels.py assume f32 accumulation). Every dot
  inside a kernel body must request it.
* **Explicit launch geometry** — ``pl.pallas_call`` without ``grid`` /
  ``out_shape`` relies on defaults that change meaning across Pallas
  versions; both must be spelled out.
* **Grid remainders** — a grid entry computed with plain floordiv
  (``m // bm``) silently *drops the remainder tile*: with m=130, bm=128
  the tail 2 rows are never computed and the output is wrong without any
  error. The enclosing function must guard divisibility (a ``%`` check
  that raises/asserts), round up (``pl.cdiv`` / ``-(-m // bm)``), or pad
  the operands before launch.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from .core import ModuleContext, Rule, Violation, dotted_name, register_rule
from .rules_jax import _kernel_fn_names, _PARTIAL_NAMES

_DOT_CALLS = ("dot_general", "dot")
_DOT_PREFIXES = ("jnp.", "jax.numpy.", "lax.", "jax.lax.")


def _is_dot(call: ast.Call) -> bool:
    fn = dotted_name(call.func)
    if not fn:
        return False
    head, _, tail = fn.rpartition(".")
    return tail in _DOT_CALLS and (head + ".").startswith(_DOT_PREFIXES)


def _f32_preferred(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "preferred_element_type":
            name = dotted_name(kw.value)
            return bool(name) and name.endswith("float32")
    return False


def _enclosing_functions(tree) -> List[ast.AST]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _floordiv_entries(grid_node) -> List[ast.AST]:
    """Grid-tuple elements computed with a plain ``a // b``."""
    if not isinstance(grid_node, (ast.Tuple, ast.List)):
        return []
    out = []
    for e in grid_node.elts:
        if isinstance(e, ast.BinOp) and isinstance(e.op, ast.FloorDiv):
            # -(-m // bm) is ceil-div: the inner floordiv sits under a
            # USub whose operand is another USub — detected by the caller
            out.append(e)
    return out


def _has_remainder_guard(fn) -> bool:
    """True when the function pads, ceil-divs, or checks divisibility."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func) or ""
            if name.endswith("cdiv") or "pad" in name.rsplit(".", 1)[-1]:
                return True
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = node.operand
            if isinstance(v, ast.BinOp) and isinstance(v.op, ast.FloorDiv) \
                    and isinstance(v.left, ast.UnaryOp) \
                    and isinstance(v.left.op, ast.USub):
                return True                      # -(-a // b)
        if isinstance(node, (ast.Assert, ast.If)):
            test = node.test
            for sub in ast.walk(test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    return True
    return False


@register_rule
class KernelContractRule(Rule):
    name = "kernel-contract"
    description = ("Pallas kernels must accumulate fp4 matmuls in f32, "
                   "declare launch geometry, and handle grid remainders")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        kernel_names = _kernel_fn_names(ctx.tree)
        fns = _enclosing_functions(ctx.tree)
        fn_by_name = {f.name: f for f in fns}

        # 1. f32 accumulation inside kernel bodies
        for name in sorted(kernel_names):
            fn = fn_by_name.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and _is_dot(node) \
                        and not _f32_preferred(node):
                    yield ctx.violation(
                        self, node,
                        f"dot in Pallas kernel body '{name}' without "
                        f"preferred_element_type=jnp.float32; bf16 "
                        f"accumulation drifts over the K loop")

        # 2./3. launch geometry + grid remainders, per pallas_call site
        for fn in fns:
            calls = [n for n in ast.walk(fn)
                     if isinstance(n, ast.Call)
                     and (dotted_name(n.func) or "").endswith("pallas_call")]
            if not calls:
                continue
            guarded = _has_remainder_guard(fn)
            # local grid assignments: grid = (m // bm, ...)
            grid_defs = {}
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    t = node.targets[0]
                    if isinstance(t, ast.Name):
                        grid_defs[t.id] = node.value
            for call in calls:
                kw = {k.arg: k.value for k in call.keywords if k.arg}
                for req in ("grid", "out_shape"):
                    if req not in kw:
                        yield ctx.violation(
                            self, call,
                            f"pallas_call without explicit {req}=; spell "
                            f"out the launch geometry")
                grid = kw.get("grid")
                if isinstance(grid, ast.Name):
                    grid = grid_defs.get(grid.id)
                if grid is None:
                    continue
                bad = _floordiv_entries(grid)
                if bad and not guarded:
                    yield ctx.violation(
                        self, bad[0],
                        "grid entry uses plain floordiv with no "
                        "divisibility guard in the enclosing function; a "
                        "non-dividing block silently drops the remainder "
                        "tile — raise on misalignment, pad, or use "
                        "pl.cdiv")
