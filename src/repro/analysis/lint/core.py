"""reprolint core: AST visitor framework, rule registry, suppressions.

The repo accumulated implicit contracts — E8M0 scale bytes in [1, 254],
nibble-packed u8 layouts, donated-buffer reuse rules, debug callbacks that
must be drained behind an effects barrier — that only surfaced as rare
runtime flakes when violated. ``reprolint`` turns them into machine-checked
rules over the Python AST. Design:

* a :class:`Rule` is a named check over one :class:`ModuleContext`
  (parsed AST + source + suppression map), registered via
  :func:`register_rule` and yielding :class:`Violation` records;
* inline suppressions: ``# reprolint: disable=rule-a,rule-b`` on the
  violating line (append ``-- reason`` for the mandatory-by-convention
  justification), ``# reprolint: disable-file=rule`` anywhere for the
  whole file;
* the committed baseline (``lint-baseline.json``, see ``baseline.py``)
  grandfathers pre-existing violations without letting new ones in.

``scripts/lint.py`` is the CLI; ``docs/static-analysis.md`` documents every
rule and how to add one.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

__all__ = [
    "Violation", "ModuleContext", "Rule", "RULES", "register_rule",
    "lint_source", "lint_file", "lint_paths", "iter_python_files",
    "dotted_name", "DEFAULT_TARGETS", "repo_root",
]

SEVERITIES = ("error", "warning")

# matches "# reprolint: disable=rule-a,rule-b -- optional justification"
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*(?P<scope>disable|disable-file)\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_,\- ]+)")

# directories linted when the CLI is given no paths (repo-relative)
DEFAULT_TARGETS = ("src/repro", "scripts", "benchmarks", "examples",
                   "experiments")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding. ``message`` is line-number-free on purpose: the
    baseline matches on (path, rule, message), so a violation keeps its
    identity when unrelated edits shift it up or down the file."""

    rule: str
    path: str              # repo-relative posix path
    line: int
    col: int
    message: str
    severity: str = "error"

    def ident(self) -> tuple:
        return (self.path, self.rule, self.message)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}: [{self.rule}] {self.message}")


class ModuleContext:
    """Everything a rule may inspect about one module."""

    def __init__(self, relpath: str, source: str, tree: ast.Module):
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        self.line_suppressions: Dict[int, Set[str]] = {}
        self.file_suppressions: Set[str] = set()
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            # a "-- reason" justification shares the character class with
            # rule names; cut it off before splitting (names never have --)
            names = m.group("rules").split("--", 1)[0]
            rules = {r.strip() for r in names.split(",") if r.strip()}
            if m.group("scope") == "disable-file":
                self.file_suppressions |= rules
            else:
                self.line_suppressions.setdefault(i, set()).update(rules)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_suppressions or "all" in self.file_suppressions:
            return True
        on_line = self.line_suppressions.get(line, ())
        return rule in on_line or "all" in on_line

    def violation(self, rule: "Rule", node, message: str) -> Violation:
        return Violation(rule.name, self.relpath,
                         getattr(node, "lineno", 1),
                         getattr(node, "col_offset", 0) + 1,
                         message, rule.severity)


class Rule:
    """Base class: subclass, set ``name``/``description``, implement
    :meth:`check` yielding violations (suppressions are applied by the
    engine, not the rule)."""

    name: str = ""
    severity: str = "error"
    description: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        raise NotImplementedError


RULES: Dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate and add to the registry."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.severity not in SEVERITIES:
        raise ValueError(f"rule {rule.name}: bad severity {rule.severity!r}")
    if rule.name in RULES:
        raise ValueError(f"rule {rule.name!r} registered twice")
    RULES[rule.name] = rule
    return cls


def dotted_name(node) -> Optional[str]:
    """'jax.debug.callback' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def repo_root() -> str:
    """The repository root (three levels above this file's src/repro)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))


def _select_rules(only: Optional[Iterable[str]]) -> List[Rule]:
    _load_builtin_rules()
    if only is None:
        return [RULES[n] for n in sorted(RULES)]
    missing = set(only) - set(RULES)
    if missing:
        raise KeyError(f"unknown rule(s) {sorted(missing)}; known: "
                       f"{', '.join(sorted(RULES))}")
    return [RULES[n] for n in sorted(only)]


_RULES_LOADED = False


def _load_builtin_rules() -> None:
    """Import the rule modules exactly once (registration is on import)."""
    global _RULES_LOADED
    if _RULES_LOADED:
        return
    from . import rules_codec, rules_env, rules_hygiene  # noqa: F401
    from . import rules_jax, rules_kernel  # noqa: F401
    _RULES_LOADED = True


def lint_source(source: str, relpath: str = "<string>",
                only: Optional[Iterable[str]] = None,
                respect_suppressions: bool = True) -> List[Violation]:
    """Lint one source string. The workhorse behind :func:`lint_file` and
    the unit-test / docs entry point."""
    rules = _select_rules(only)
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation("parse-error", relpath, e.lineno or 1,
                          (e.offset or 0) + 1,
                          f"file does not parse: {e.msg}")]
    ctx = ModuleContext(relpath, source, tree)
    out = []
    for rule in rules:
        for v in rule.check(ctx):
            if respect_suppressions and ctx.suppressed(v.rule, v.line):
                continue
            out.append(v)
    return sorted(out, key=lambda v: (v.path, v.line, v.col, v.rule))


def lint_file(path: str, root: Optional[str] = None,
              only: Optional[Iterable[str]] = None) -> List[Violation]:
    root = root or repo_root()
    rel = os.path.relpath(os.path.abspath(path), root)
    with open(path, encoding="utf-8") as f:
        source = f.read()
    return lint_source(source, rel, only)


def iter_python_files(paths: Sequence[str], root: Optional[str] = None
                      ) -> List[str]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    root = root or repo_root()
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            out.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f) for f in filenames
                       if f.endswith(".py"))
    return sorted(set(out))


def lint_paths(paths: Optional[Sequence[str]] = None,
               root: Optional[str] = None,
               only: Optional[Iterable[str]] = None) -> List[Violation]:
    """Lint files/directories (default: the repo's runtime tree)."""
    root = root or repo_root()
    files = iter_python_files(paths or DEFAULT_TARGETS, root)
    out = []
    for f in files:
        out.extend(lint_file(f, root, only))
    return out
