"""env-hygiene: REPRO_* reads must go through repro.core.envflags.

Raw ``os.environ`` reads each re-implement parsing ("1" vs truthy, int
validation, choice checking) and drift apart; the typed accessor module
declares every flag once (name, type, default, docstring) and the docs
table is generated from it. *Writes* (``os.environ[...] = ...``,
``setdefault`` in launchers/benches, test monkeypatching) are deliberately
exempt — setting a flag is configuration, reading one is behavior.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import ModuleContext, Rule, Violation, dotted_name, register_rule

# the one module allowed to touch os.environ for REPRO_* reads
_ALLOWED = ("src/repro/core/envflags.py",)

_READ_CALLS = ("os.environ.get", "environ.get", "os.getenv", "getenv")
_ENV_OBJS = ("os.environ", "environ")


def _repro_key(node) -> str:
    """The literal env-var name if it is a REPRO_* string constant."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str) \
            and node.value.startswith("REPRO_"):
        return node.value
    return ""


@register_rule
class EnvHygieneRule(Rule):
    name = "env-hygiene"
    description = ("REPRO_* environment reads outside repro.core.envflags "
                   "(use the typed get_bool/get_int/get_str accessors)")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        if ctx.relpath.replace("\\", "/") in _ALLOWED:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in _READ_CALLS and node.args:
                    key = _repro_key(node.args[0])
                    if key:
                        yield ctx.violation(
                            self, node,
                            f"raw environment read of {key}: declare it in "
                            f"repro.core.envflags and use the typed "
                            f"accessor")
            elif isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Load) \
                    and dotted_name(node.value) in _ENV_OBJS:
                key = _repro_key(node.slice)
                if key:
                    yield ctx.violation(
                        self, node,
                        f"raw environment read of {key}: declare it in "
                        f"repro.core.envflags and use the typed accessor")
            elif isinstance(node, ast.Compare) \
                    and len(node.comparators) == 1 \
                    and isinstance(node.ops[0], ast.In) \
                    and dotted_name(node.comparators[0]) in _ENV_OBJS:
                key = _repro_key(node.left)
                if key:
                    yield ctx.violation(
                        self, node,
                        f"membership test on {key} in os.environ: declare "
                        f"it in repro.core.envflags and use the typed "
                        f"accessor")
