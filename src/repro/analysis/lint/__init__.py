"""reprolint: AST-based static analysis for this repo's own invariants.

Generic linters can't know that a ``Codec`` needs a matched encode/decode
pair, that a Pallas grid computed with plain floordiv drops its remainder
tile, or that ``REPRO_*`` knobs must flow through the typed registry in
``repro.core.envflags``. These rules encode exactly those contracts.

Entry points: ``scripts/lint.py`` (CLI), :func:`lint_source` /
:func:`lint_paths` (library), ``lint-baseline.json`` (accepted debt).
"""
from .core import (DEFAULT_TARGETS, RULES, ModuleContext, Rule, Violation,
                   _load_builtin_rules, lint_file, lint_paths, lint_source,
                   register_rule)
from .baseline import (baseline_path, diff_against_baseline, load_baseline,
                       save_baseline)
from .report import render_json, render_summary, render_text, rule_counts
from .cli import main

_load_builtin_rules()    # populate RULES at import so the registry is whole

__all__ = [
    "DEFAULT_TARGETS",
    "RULES",
    "ModuleContext",
    "Rule",
    "Violation",
    "lint_file",
    "lint_paths",
    "lint_source",
    "register_rule",
    "baseline_path",
    "diff_against_baseline",
    "load_baseline",
    "save_baseline",
    "render_json",
    "render_summary",
    "render_text",
    "rule_counts",
    "main",
]
