"""jit-safety rules: donated-buffer reuse, undrained debug callbacks,
host/tracer leaks inside traced code.

These encode the hazards the serving engine actually hit while growing:

* ``donated-reuse`` — a buffer passed at a ``donate_argnums`` position is
  consumed by the launch; reading the stale reference afterwards is
  undefined (XLA may have aliased the memory into the output). The rule
  tracks ``f = jax.jit(fn, donate_argnums=...)`` bindings (including
  ``self.attr = jax.jit(...)`` across the methods of a class) and flags
  any later load of a donated argument that was not rebound first.
* ``undrained-callback`` — ``jax.debug.callback`` side effects are
  asynchronous; a module that registers them but never references
  ``jax.effects_barrier`` can lose or reorder deliveries at shutdown /
  checkpoint boundaries (the serve guard drains its mailbox behind a
  barrier after every launch). Modules whose callbacks are drained by a
  *different* module carry an inline suppression saying which one.
* ``tracer-leak`` — ``.item()``, ``float()``/``int()``/``bool()`` of a
  traced parameter, ``np.asarray``, or Python branching on a ``jnp.``
  expression inside a jit-decorated function or a Pallas kernel body:
  each either forces a blocking host sync or raises a TracerError at a
  call site far from the mistake.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .core import ModuleContext, Rule, Violation, dotted_name, register_rule

_JIT_NAMES = ("jax.jit", "jit")
_PARTIAL_NAMES = ("functools.partial", "partial")


def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """The static donate_argnums of a jax.jit(...) call, if present."""
    if dotted_name(call.func) not in _JIT_NAMES:
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            out = []
            for e in v.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    out.append(e.value)
            return tuple(out)
    return None


def _target_names(target) -> List[str]:
    """Dotted names bound by an assignment target."""
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    name = dotted_name(target)
    return [name] if name else []


def _units(stmts) -> Iterator[Tuple[ast.AST, List[ast.AST]]]:
    """Flatten a statement list into (header-node, expr-subtrees) units in
    source order. Compound statements contribute a unit for their header
    expressions, then recurse into their bodies; nested function/class
    definitions are opaque (their bodies run later, under different
    aliasing rules)."""
    for s in stmts:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        if isinstance(s, ast.If) or isinstance(s, ast.While):
            yield s, [s.test]
            yield from _units(s.body)
            yield from _units(s.orelse)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            yield s, [s.iter, s.target]
            yield from _units(s.body)
            yield from _units(s.orelse)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            yield s, [i.context_expr for i in s.items] + \
                [i.optional_vars for i in s.items if i.optional_vars]
            yield from _units(s.body)
        elif isinstance(s, ast.Try):
            yield from _units(s.body)
            for h in s.handlers:
                yield from _units(h.body)
            yield from _units(s.orelse)
            yield from _units(s.finalbody)
        else:
            yield s, [s]


def _walk_exprs(subtrees) -> Iterator[ast.AST]:
    for t in subtrees:
        for node in ast.walk(t):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            yield node


@register_rule
class DonatedReuseRule(Rule):
    name = "donated-reuse"
    description = ("use of a buffer after it was passed at a donated "
                   "argument position of a jitted callable")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        # class-level map: "self.attr" -> donated positions, per ClassDef
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(ctx, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, node, {})

    def _check_class(self, ctx, cls) -> Iterator[Violation]:
        donating: Dict[str, Tuple[int, ...]] = {}
        for m in cls.body:
            if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(m):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.value, ast.Call):
                    tgt = dotted_name(node.targets[0])
                    pos = _donate_positions(node.value)
                    if tgt and tgt.startswith("self.") and pos:
                        donating[tgt] = pos
        for m in cls.body:
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, m, donating)

    def _check_function(self, ctx, fn, inherited) -> Iterator[Violation]:
        donating = dict(inherited)
        # local bindings: f = jax.jit(..., donate_argnums=...)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.value, ast.Call):
                tgt = dotted_name(node.targets[0])
                pos = _donate_positions(node.value)
                if tgt and pos:
                    donating[tgt] = pos
        if not donating:
            return
        dead: Dict[str, int] = {}       # name -> line it was donated on
        for header, subtrees in _units(fn.body):
            # 1. loads of dead names
            for node in _walk_exprs(subtrees):
                if not isinstance(node, (ast.Name, ast.Attribute)):
                    continue
                if not isinstance(getattr(node, "ctx", None), ast.Load):
                    continue
                name = dotted_name(node)
                if name in dead:
                    yield ctx.violation(
                        self, node,
                        f"'{name}' was donated to a jitted call (line "
                        f"{dead[name]} donates it via donate_argnums) and "
                        f"read again without being rebound; the buffer "
                        f"may already be aliased into the output")
                    del dead[name]       # report once per donation
            # 2. consumptions
            for node in _walk_exprs(subtrees):
                if not isinstance(node, ast.Call):
                    continue
                callee = dotted_name(node.func)
                pos = donating.get(callee) if callee else None
                if pos is None and isinstance(node.func, ast.Call):
                    pos = _donate_positions(node.func)   # jax.jit(f,...)(x)
                if not pos:
                    continue
                for p in pos:
                    if p < len(node.args):
                        name = dotted_name(node.args[p])
                        if name:
                            dead[name] = node.lineno
            # 3. rebindings resurrect
            for node in _walk_exprs(subtrees):
                bound: List[str] = []
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        bound.extend(_target_names(t))
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    bound.extend(_target_names(node.target))
                elif isinstance(node, ast.NamedExpr):
                    bound.extend(_target_names(node.target))
                elif isinstance(node, ast.Delete):
                    for t in node.targets:
                        bound.extend(_target_names(t))
                for name in bound:
                    dead.pop(name, None)
            if isinstance(header, (ast.For, ast.AsyncFor)):
                for name in _target_names(header.target):
                    dead.pop(name, None)


@register_rule
class UndrainedCallbackRule(Rule):
    name = "undrained-callback"
    description = ("jax.debug.callback registered in a module that never "
                   "references jax.effects_barrier")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        callbacks = []
        drained = False
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn and fn.endswith("debug.callback"):
                    callbacks.append(node)
            if (isinstance(node, ast.Attribute)
                    and node.attr == "effects_barrier") \
                    or (isinstance(node, ast.Name)
                        and node.id == "effects_barrier"):
                drained = True
        if drained:
            return
        for node in callbacks:
            yield ctx.violation(
                self, node,
                "jax.debug.callback registered but this module never calls "
                "jax.effects_barrier; drain deliveries behind a barrier, or "
                "suppress naming the module that drains them")


def _jit_decorated(fn) -> bool:
    for d in fn.decorator_list:
        if dotted_name(d) in _JIT_NAMES:
            return True
        if isinstance(d, ast.Call):
            if dotted_name(d.func) in _JIT_NAMES:
                return True
            if dotted_name(d.func) in _PARTIAL_NAMES and d.args \
                    and dotted_name(d.args[0]) in _JIT_NAMES:
                return True
    return False


def _kernel_fn_names(tree) -> Set[str]:
    """Names of functions passed (possibly via functools.partial) as the
    first argument of a ``pallas_call``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted_name(node.func)
        if not fn or not fn.endswith("pallas_call") or not node.args:
            continue
        arg0 = node.args[0]
        if isinstance(arg0, ast.Name):
            out.add(arg0.id)
        elif isinstance(arg0, ast.Call) \
                and dotted_name(arg0.func) in _PARTIAL_NAMES and arg0.args \
                and isinstance(arg0.args[0], ast.Name):
            out.add(arg0.args[0].id)
    return out


_NP_CONVERSIONS = ("np.asarray", "np.array", "numpy.asarray", "numpy.array")
_TRACED_PREFIXES = ("jnp.", "jax.numpy.")


@register_rule
class TracerLeakRule(Rule):
    name = "tracer-leak"
    description = ("host sync / Python control flow on traced values "
                   "inside jit or Pallas kernel bodies")

    def check(self, ctx: ModuleContext) -> Iterator[Violation]:
        kernel_names = _kernel_fn_names(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not (_jit_decorated(fn) or fn.name in kernel_names):
                continue
            # positional parameters carry traced values; kw-only params are
            # the static_argnames / functools.partial configuration channel
            params = {a.arg for a in fn.args.args + fn.args.posonlyargs}
            params.discard("self")
            yield from self._check_body(ctx, fn, params)

    def _check_body(self, ctx, fn, params) -> Iterator[Violation]:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = dotted_name(node.func)
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    yield ctx.violation(
                        self, node,
                        ".item() inside a traced function forces a "
                        "blocking device->host sync (TracerError under "
                        "jit); keep reductions on-device or move the read "
                        "outside the traced scope")
                elif callee in _NP_CONVERSIONS and node.args:
                    yield ctx.violation(
                        self, node,
                        f"{callee}() materializes a traced value on the "
                        f"host; use jnp inside traced code")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in ("float", "int", "bool") \
                        and len(node.args) == 1 \
                        and self._mentions(node.args[0], params):
                    yield ctx.violation(
                        self, node,
                        f"{node.func.id}() of a traced argument raises "
                        f"TracerError under jit; use jnp casts "
                        f"(.astype) instead")
            elif isinstance(node, (ast.If, ast.While)):
                if self._has_traced_call(node.test):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield ctx.violation(
                        self, node,
                        f"Python `{kind}` on a jnp expression inside a "
                        f"traced function branches on a tracer; use "
                        f"jnp.where / lax.cond / pl.when")

    @staticmethod
    def _mentions(expr, params) -> bool:
        return any(isinstance(n, ast.Name) and n.id in params
                   for n in ast.walk(expr))

    @staticmethod
    def _has_traced_call(test) -> bool:
        for n in ast.walk(test):
            if isinstance(n, ast.Call):
                fn = dotted_name(n.func)
                if fn and fn.startswith(_TRACED_PREFIXES):
                    return True
        return False
