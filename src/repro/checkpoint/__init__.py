from .checkpoint import (  # noqa: F401
    CheckpointManager, latest_step, restore_state, save_state,
)
