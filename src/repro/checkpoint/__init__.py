from .checkpoint import (  # noqa: F401
    CheckpointManager, latest_step, read_manifest, restore_state, save_state,
)
