from .checkpoint import (  # noqa: F401
    CheckpointCorruptError, CheckpointManager, all_steps, latest_step,
    leaf_crc32, read_manifest, restore_state, save_state,
)

__all__ = [
    "CheckpointCorruptError", "CheckpointManager", "all_steps",
    "latest_step", "leaf_crc32", "read_manifest", "restore_state",
    "save_state",
]
