"""Fault-tolerant sharded checkpointing (no orbax available offline).

Properties needed for 1000+ node operation:
  * atomic: write to ``step_N.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint; restore scans for the newest *complete*
    step directory.
  * async: device->host transfer happens on the caller thread (cheap,
    device_get), file IO runs on a background thread so the train loop
    doesn't stall.
  * elastic: leaves are saved as full *logical* arrays keyed by pytree path
    — restore re-shards onto whatever mesh the new job brings up (chip count
    can change between runs).
  * bounded: keeps the newest ``keep`` checkpoints, deletes older ones.
  * self-describing: manifest.json records step, key paths, shapes, dtypes,
    and the data-pipeline step for exact stream resume.

Multi-host note: in a true multi-controller deployment each host calls
``save_state`` with ``host_shard_only=True`` writing its addressable shards
(path suffix .host<i>) and host 0 writes the manifest; this container is
single-process so the default path saves full arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_state", "restore_state", "read_manifest", "latest_step",
           "all_steps", "CheckpointManager", "CheckpointCorruptError",
           "leaf_crc32"]

_MANIFEST = "manifest.json"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint's on-disk bytes are damaged (truncation, bit flip, bad
    media). ``leaf`` names the first array that failed to read or verify
    when that is determinable, else None (e.g. the npz container itself is
    unreadable). The message always says what to do next: restore an older
    step or re-write the checkpoint from source."""

    def __init__(self, message: str, leaf: Optional[str] = None,
                 ckpt_dir: Optional[str] = None):
        super().__init__(message)
        self.leaf = leaf
        self.ckpt_dir = ckpt_dir


def leaf_crc32(arr: np.ndarray) -> int:
    """CRC-32 of an array's raw bytes (dtype-agnostic: extension dtypes
    like bfloat16 hash the same bytes the npz stores)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten(state):
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_state(ckpt_dir: str, step: int, state, extra: Optional[dict] = None,
               keep: int = 3) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    from repro.obs import span
    with span("checkpoint.save", cat="ckpt", dir=ckpt_dir, step=step):
        return _save_state(ckpt_dir, step, state, extra, keep)


def _save_state(ckpt_dir, step, state, extra, keep) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    arrays = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[key.replace("/", "|")] = arr
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": leaf_crc32(arr)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomicity point

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(ckpt_dir, name, _MANIFEST)
            if os.path.exists(full):           # complete checkpoints only
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """Manifest of a checkpoint (leaf shapes/dtypes + ``extra``) without
    touching the arrays — cheap format/compatibility checks before a full
    restore."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"step_{step:010d}", _MANIFEST)) as f:
        return json.load(f)


def restore_state(ckpt_dir: str, template, step: Optional[int] = None,
                  shardings=None, verify: bool = True):
    """Restore into the structure of ``template`` (a state pytree or its
    eval_shape). ``shardings``: optional matching tree of NamedShardings —
    arrays are placed (and re-sharded if the mesh changed) on load.
    ``verify``: check every leaf's bytes against the CRC-32 the manifest
    recorded at save time (one hash pass per leaf; checkpoints written
    before CRCs existed load unverified). Returns (state, manifest_extra).

    Raises :class:`CheckpointCorruptError` — naming the bad leaf whenever
    the container is readable enough to know it — when the npz is
    truncated/unreadable, a leaf is missing, or a leaf fails CRC."""
    from repro.obs import span
    with span("checkpoint.restore", cat="ckpt", dir=ckpt_dir,
              step=-1 if step is None else step):
        return _restore_state(ckpt_dir, template, step, shardings, verify)


_REMEDY = ("the checkpoint bytes are damaged — restore an older step "
           "(repro.checkpoint.all_steps) or re-write it from source")


def _restore_state(ckpt_dir, template, step=None, shardings=None,
                   verify=True):
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    npz_path = os.path.join(d, "arrays.npz")
    try:
        npz = np.load(npz_path)
    except Exception as e:
        raise CheckpointCorruptError(
            f"{npz_path} is unreadable ({type(e).__name__}: {e}); "
            f"{_REMEDY}", ckpt_dir=ckpt_dir) from e

    flat_t = jax.tree_util.tree_flatten_with_path(template)[0]
    tdef = jax.tree_util.tree_structure(template)
    flat_s = (jax.tree_util.tree_flatten_with_path(shardings)[0]
              if shardings is not None else None)
    leaves = []
    for i, (path, leaf) in enumerate(flat_t):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        try:
            arr = npz[key.replace("/", "|")]
        except KeyError:
            raise CheckpointCorruptError(
                f"{npz_path} holds no array for leaf {key!r}; {_REMEDY}",
                leaf=key, ckpt_dir=ckpt_dir) from None
        except Exception as e:
            raise CheckpointCorruptError(
                f"leaf {key!r} of {npz_path} failed to read "
                f"({type(e).__name__}: {e}); {_REMEDY}",
                leaf=key, ckpt_dir=ckpt_dir) from e
        want_crc = manifest["leaves"].get(key, {}).get("crc32")
        if verify and want_crc is not None:
            got_crc = leaf_crc32(arr)
            if got_crc != want_crc:
                raise CheckpointCorruptError(
                    f"leaf {key!r} of {npz_path} failed CRC-32 "
                    f"verification (manifest 0x{want_crc:08x}, on disk "
                    f"0x{got_crc:08x}); {_REMEDY}",
                    leaf=key, ckpt_dir=ckpt_dir)
        if arr.dtype.kind == "V":
            # npz stores extension dtypes (bfloat16, float8_*) as raw void
            # bytes; the manifest remembers the real dtype — view it back
            import ml_dtypes
            want = manifest["leaves"][key]["dtype"]
            arr = arr.view(np.dtype(getattr(ml_dtypes, want)))
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {expect}")
        if flat_s is not None:
            leaves.append(jax.device_put(arr, flat_s[i][1]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, leaves), manifest["extra"]


class CheckpointManager:
    """Async checkpointing + auto-resume + preemption-safe final save."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every
        self._thread: Optional[threading.Thread] = None
        self._last_saved = -1

    def maybe_save(self, step: int, state, extra: Optional[dict] = None,
                   force: bool = False):
        if not force and step % self.every != 0:
            return False
        self.wait()                             # one in flight at a time
        # device_get on caller thread (consistent snapshot), IO on worker
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                state)

        def work():
            save_state(self.dir, step, snapshot, extra, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self._last_saved = step
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def resume(self, template, shardings=None):
        """Returns (state, extra, step) from the newest complete checkpoint,
        or (None, None, None)."""
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        state, extra = restore_state(self.dir, template, step, shardings)
        return state, extra, step
