# Deterministic fault injection for the serving stack — the harness behind
# tests/test_faults.py and `benchmarks/serve_bench.py --chaos`.
from .faults import (  # noqa: F401
    FaultInjector, FaultPlan, chaos_plan, corrupt_checkpoint_leaf,
    poison_kv_nan, poison_kv_scale, truncate_checkpoint,
)

__all__ = [
    "FaultInjector", "FaultPlan", "chaos_plan", "corrupt_checkpoint_leaf",
    "poison_kv_nan", "poison_kv_scale", "truncate_checkpoint",
]
