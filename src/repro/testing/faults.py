"""Deterministic, seed-driven fault injection for the serving stack.

Robustness claims need a harness that *produces* the faults they guard
against, reproducibly. This module injects the four fault classes the
engine's guard (``repro.serve.guard``) is built to contain:

* **bit flips in packed streams** — :func:`poison_kv_scale` writes the
  reserved scale byte 255 into one slot's packed-KV page (what a flipped
  high bit does to a legal E8M0 byte); :func:`corrupt_checkpoint_leaf`
  flips one bit of one array inside a written checkpoint (CRC-32 must
  catch it on load and name the leaf).
* **NaN activations** — a chosen slot's logit row is overwritten with NaN
  after a launch (:class:`FaultInjector`), or a float KV page entry is
  poisoned directly (:func:`poison_kv_nan`).
* **truncated checkpoints** — :func:`truncate_checkpoint` cuts the npz
  container short (restore must raise ``CheckpointCorruptError``, not
  unpickle garbage).
* **delayed / failed steps** — a launch sleeps past the watchdog budget,
  or raises ``TransientStepError`` *before* invoking the jitted function
  (critically: the engine's launches donate their cache buffers, so a
  retryable fault must fire before the call consumes them — this harness
  guarantees that, making the engine's retry path safe to exercise).

Everything is keyed on the engine's step counter and a
:class:`FaultPlan`; the same seed always yields the same fault schedule
(:func:`chaos_plan`), so chaos runs are replayable and the survivor-token
bit-exactness assertions in tests/test_faults.py are deterministic.

Usage::

    plan = chaos_plan(seed=7, n_slots=4, first_step=2, horizon=40)
    with FaultInjector(eng, plan) as inj:
        eng.run()
    assert eng.health != "failed"
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serve.guard import TransientStepError

__all__ = [
    "FaultPlan", "FaultInjector", "chaos_plan",
    "poison_kv_scale", "poison_kv_nan",
    "corrupt_checkpoint_leaf", "truncate_checkpoint",
]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule, keyed on the engine step counter
    (``engine.stats.steps`` at launch time). Each entry fires exactly once
    — a launch retried after a transient failure sees no new fault."""

    seed: int = 0
    nan_logit_steps: Tuple[Tuple[int, int], ...] = ()   # (step, slot)
    kv_poison_steps: Tuple[Tuple[int, int], ...] = ()   # (step, slot)
    fail_steps: Tuple[int, ...] = ()                    # TransientStepError
    delay_steps: Tuple[Tuple[int, float], ...] = ()     # (step, seconds)

    def describe(self) -> str:
        return (f"FaultPlan(seed={self.seed}, "
                f"nan_logits={list(self.nan_logit_steps)}, "
                f"kv_poison={list(self.kv_poison_steps)}, "
                f"fails={list(self.fail_steps)}, "
                f"delays={list(self.delay_steps)})")


def chaos_plan(seed: int, n_slots: int, first_step: int = 2,
               horizon: int = 40, delay_s: float = 0.0) -> FaultPlan:
    """One representative fault of each class at seed-determined steps in
    ``[first_step, first_step + horizon)`` — distinct steps, distinct
    slots, so every containment path is exercised independently.
    ``first_step`` must be past jit warmup when a watchdog is armed."""
    rng = np.random.default_rng(seed)
    steps = first_step + rng.choice(max(4, horizon), size=4, replace=False)
    slots = rng.choice(n_slots, size=2, replace=n_slots < 2)
    return FaultPlan(
        seed=seed,
        nan_logit_steps=((int(steps[0]), int(slots[0])),),
        kv_poison_steps=((int(steps[1]), int(slots[1])),),
        fail_steps=(int(steps[2]),),
        delay_steps=(((int(steps[3]), delay_s),) if delay_s > 0 else ()),
    )


# ---------------------------------------------------------------------------
# Cache poisoning (host-side, functional: returns a new cache tree)
# ---------------------------------------------------------------------------

def _leaf_items(tree):
    import jax
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], tdef


def _replace_leaf(tree, pick_fn, mutate_fn):
    import jax
    keys, leaves, tdef = _leaf_items(tree)
    idx = pick_fn(keys, leaves)
    if idx is None:
        raise ValueError("no matching cache leaf to poison")
    leaves = list(leaves)
    leaves[idx] = mutate_fn(leaves[idx])
    return jax.tree_util.tree_unflatten(tdef, leaves), keys[idx]


def poison_kv_scale(caches, slot: int):
    """Write the reserved byte 255 over one entry of the first packed-KV
    u8 ``scales`` stream in ``slot``'s page (what a flipped high bit does
    to a legal E8M0 byte ~128). Returns (poisoned caches, leaf path).
    Requires a quantized-KV config (``cfg.kv_quant``)."""
    import jax.numpy as jnp

    def pick(keys, leaves):
        for i, (k, l) in enumerate(zip(keys, leaves)):
            if k.endswith("scales") and l.dtype == jnp.uint8 and l.ndim >= 2:
                return i
        return None

    def mutate(leaf):
        # last page position: not overwritten by the slot's next KV write
        pos = leaf.shape[2] - 1 if leaf.ndim >= 3 else 0
        at = (0, slot, pos)[:leaf.ndim] + (0,) * max(0, leaf.ndim - 3)
        return leaf.at[at].set(255)

    return _replace_leaf(caches, pick, mutate)


def poison_kv_nan(caches, slot: int):
    """NaN one entry of the first float K/V page (dense-KV configs) in
    ``slot``'s row. Returns (poisoned caches, leaf path)."""
    import jax.numpy as jnp

    def pick(keys, leaves):
        for i, (k, l) in enumerate(zip(keys, leaves)):
            if jnp.issubdtype(l.dtype, jnp.floating) and l.ndim >= 3 \
                    and not any(s in k for s in ("mlstm", "slstm", "mamba")):
                return i
        return None

    def mutate(leaf):
        pos = leaf.shape[2] - 1 if leaf.ndim >= 3 else 0
        at = (0, slot, pos)[:leaf.ndim] + (0,) * max(0, leaf.ndim - 3)
        return leaf.at[at].set(jnp.nan)

    return _replace_leaf(caches, pick, mutate)


# ---------------------------------------------------------------------------
# Launch interception
# ---------------------------------------------------------------------------

class FaultInjector:
    """Wraps a ``ServeEngine``'s jitted launches and fires the plan's
    faults at their scheduled steps. Use as a context manager (restores
    the original launches on exit)::

        with FaultInjector(engine, plan) as inj:
            engine.run()
        inj.fired   # {(kind, step), ...} — what actually triggered

    Fault semantics at a scheduled step:

    ``fail``   raise :class:`TransientStepError` *before* the jitted call
               (donated buffers untouched — retry-safe by construction).
    ``delay``  sleep before the call (trips the engine watchdog).
    ``kv``     poison the cache argument (scale byte 255 on quantized-KV
               configs, NaN float on dense-KV) for the planned slot.
    ``nan``    overwrite the planned slot's logit row with NaN after the
               call returns.
    """

    def __init__(self, engine, plan: FaultPlan):
        self.engine = engine
        self.plan = plan
        self.fired: set = set()
        self._orig_step = None
        self._orig_prefill = None

    # -- plan lookup (fire-once) -------------------------------------------

    def _take(self, kind: str, step: int):
        table = {
            "nan": dict(self.plan.nan_logit_steps),
            "kv": dict(self.plan.kv_poison_steps),
            "fail": {s: True for s in self.plan.fail_steps},
            "delay": dict(self.plan.delay_steps),
        }[kind]
        if step in table and (kind, step) not in self.fired:
            self.fired.add((kind, step))
            return table[step]
        return None

    # -- wrappers ----------------------------------------------------------

    def _pre(self, caches):
        step = self.engine.stats.steps
        delay = self._take("delay", step)
        if delay is not None:
            time.sleep(float(delay))
        if self._take("fail", step) is not None:
            raise TransientStepError(
                f"injected transient failure at step {step} "
                f"(seed {self.plan.seed})")
        slot = self._take("kv", step)
        if slot is not None:
            try:
                caches, _ = poison_kv_scale(caches, slot)
            except ValueError:
                caches, _ = poison_kv_nan(caches, slot)
        return caches

    def _post_logits(self, logits):
        import jax.numpy as jnp
        slot = self._take("nan", self.engine.stats.steps)
        if slot is not None:
            logits = logits.at[slot].set(jnp.nan)
        return logits

    def install(self) -> "FaultInjector":
        eng = self.engine
        if self._orig_step is not None:
            return self
        self._orig_step = eng._step
        self._orig_prefill = eng._prefill

        def step(p, b, c, i):
            c = self._pre(c)
            logits, c2 = self._orig_step(p, b, c, i)
            return self._post_logits(logits), c2

        def prefill(p, b, c, i, l):
            c = self._pre(c)
            logits, c2 = self._orig_prefill(p, b, c, i, l)
            return self._post_logits(logits), c2

        eng._step = step
        eng._prefill = prefill
        return self

    def uninstall(self) -> None:
        if self._orig_step is not None:
            self.engine._step = self._orig_step
            self.engine._prefill = self._orig_prefill
            self._orig_step = self._orig_prefill = None

    def __enter__(self) -> "FaultInjector":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()


# ---------------------------------------------------------------------------
# On-disk checkpoint corruption
# ---------------------------------------------------------------------------

def _ckpt_npz(ckpt_dir: str, step: Optional[int]) -> str:
    from repro.checkpoint import latest_step
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    return os.path.join(ckpt_dir, f"step_{step:010d}", "arrays.npz")


def corrupt_checkpoint_leaf(ckpt_dir: str, step: Optional[int] = None,
                            leaf: Optional[str] = None,
                            seed: int = 0) -> str:
    """Flip one bit of one array inside a written checkpoint and re-write
    the npz (container stays well-formed, so only the manifest CRC-32 can
    catch it). ``leaf`` picks the manifest key to damage (seed-chosen
    otherwise). Returns the damaged leaf's manifest key."""
    path = _ckpt_npz(ckpt_dir, step)
    with np.load(path) as npz:
        arrays = {k: npz[k] for k in npz.files}
    rng = np.random.default_rng(seed)
    keys = sorted(arrays)
    key = leaf.replace("/", "|") if leaf is not None \
        else keys[rng.integers(len(keys))]
    arr = arrays[key]
    raw = bytearray(arr.tobytes())
    if not raw:
        raise ValueError(f"leaf {key!r} has no bytes to corrupt")
    bit = int(rng.integers(8 * len(raw)))
    raw[bit // 8] ^= 1 << (bit % 8)
    arrays[key] = np.frombuffer(bytes(raw), dtype=arr.dtype
                                ).reshape(arr.shape)
    np.savez(path, **arrays)
    return key.replace("|", "/")


def truncate_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                        nbytes: int = 256) -> str:
    """Truncate a checkpoint's npz container to ``nbytes`` (a crash or
    full disk mid-copy). Returns the truncated file path."""
    path = _ckpt_npz(ckpt_dir, step)
    with open(path, "r+b") as f:
        f.truncate(nbytes)
    return path
