"""Deterministic, host-sharded data pipeline.

Design requirements at 1000+ node scale:
  * determinism keyed by (seed, step, host) — any host can regenerate any
    batch, so restart/elastic-reshard replays the exact token stream with no
    data service round-trip;
  * no host reads more than its shard (batch dim split over hosts);
  * background prefetch thread overlaps host data generation with device
    compute.

Two sources:
  * SyntheticLM — a *learnable* synthetic stream: each sequence repeats a
    per-sequence random motif with noise, so next-token loss has real signal
    (used by examples/ and the accuracy-proxy benchmark).
  * ByteCorpus — byte-level tokenization of a real text file with seeded
    window sampling.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "ByteCorpus", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int                    # GLOBAL batch
    seq: int
    vocab: int
    seed: int = 0
    motif_len: int = 16           # SyntheticLM pattern length
    noise: float = 0.02
    host_id: int = 0
    num_hosts: int = 1


class SyntheticLM:
    """Deterministic learnable stream: seq = repeated random motif + noise."""

    def __init__(self, cfg: DataConfig):
        assert cfg.batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.batch // cfg.num_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        out = np.empty((self.local_batch, cfg.seq + 1), np.int32)
        for i in range(self.local_batch):
            # key: (seed, step, global row index) -> independent Philox
            row = cfg.host_id * self.local_batch + i
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed, counter=[0, 0, step, row]))
            m = cfg.motif_len
            motif = rng.integers(0, cfg.vocab, m)
            reps = (cfg.seq + 1 + m - 1) // m
            seq = np.tile(motif, reps)[: cfg.seq + 1]
            flip = rng.random(cfg.seq + 1) < cfg.noise
            seq = np.where(flip, rng.integers(0, cfg.vocab, cfg.seq + 1), seq)
            out[i] = seq
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteCorpus:
    """Byte-level LM windows over a text file, seeded window sampling."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        with open(path, "rb") as f:
            data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(data) > cfg.seq + 1, "corpus too small"
        self.data = data.astype(np.int32) % cfg.vocab
        self.local_batch = cfg.batch // cfg.num_hosts

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed + 1, counter=[0, 0, step, cfg.host_id]))
        starts = rng.integers(0, len(self.data) - cfg.seq - 1,
                              self.local_batch)
        rows = np.stack([self.data[s:s + cfg.seq + 1] for s in starts])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any batch source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2)
