# Data substrate: deterministic synthetic LM streams + byte-corpus
# tokenization, host-sharded with background prefetch.
from .pipeline import DataConfig, SyntheticLM, ByteCorpus, Prefetcher  # noqa: F401

__all__ = [
    "ByteCorpus", "DataConfig", "Prefetcher", "SyntheticLM",
]
