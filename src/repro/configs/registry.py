"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "olmoe-1b-7b", "mixtral-8x22b", "qwen2.5-14b", "qwen2-0.5b",
    "gemma2-9b", "qwen3-8b", "musicgen-large", "pixtral-12b",
    "xlstm-125m", "zamba2-7b", "paper-llama2-7b",
)

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma2-9b": "gemma2_9b",
    "qwen3-8b": "qwen3_8b",
    "musicgen-large": "musicgen_large",
    "pixtral-12b": "pixtral_12b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-7b": "zamba2_7b",
    "paper-llama2-7b": "paper_llama2_7b",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, **overrides):
    cfg = _module(name).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(name: str, **overrides):
    cfg = _module(name).SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_configs():
    return list(ARCHS)
