"""Pixtral-12B — multimodal decoder backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

Vision frontend (Pixtral-ViT) is a STUB per the assignment: input_specs()
provides precomputed patch+text embeddings (B, S, d_model); the
mistral-nemo-style decoder backbone is implemented fully."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    input_mode="embeddings",
    rope_theta=1_000_000_000.0,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, input_mode="embeddings",
)
