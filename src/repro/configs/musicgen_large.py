"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

Modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); the backbone transformer and
the 2048-way codebook head are implemented fully."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    input_mode="embeddings",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, input_mode="embeddings",
)
