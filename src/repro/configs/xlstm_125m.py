"""xLSTM-125M — alternating mLSTM / sLSTM blocks [arXiv:2405.04517;
unverified]. d_ff=0: no separate FFN (projections live inside blocks).
Constant-size recurrent state -> long_500k capable."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_kinds=tuple(("mlstm" if i % 2 == 0 else "slstm")
                      for i in range(12)),
    long_context_ok=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=256,
    block_kinds=("mlstm", "slstm"), long_context_ok=True,
)
