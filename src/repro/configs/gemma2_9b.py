"""Gemma2-9B — local/global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True, long_context_ok=True,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="gemma2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    local_global=True, sliding_window=32,
    attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    long_context_ok=True,
)
