"""Qwen2.5-14B — dense GQA with QKV bias [hf:Qwen/Qwen2.5-14B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, qkv_bias=True,
)
