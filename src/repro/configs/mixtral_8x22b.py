"""Mixtral-8x22B — 8-expert top-2 MoE with SWA [arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    n_experts=8, experts_per_token=2,
    sliding_window=4096,               # bounded KV cache -> long_500k ok
    long_context_ok=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    n_experts=4, experts_per_token=2, moe_group_size=64,
    sliding_window=32, long_context_ok=True,
)
