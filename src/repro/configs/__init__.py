"""Assigned architecture configs (one module per arch) + registry."""
from .registry import ARCHS, get_config, list_configs, smoke_config  # noqa: F401

__all__ = [
    "ARCHS", "get_config", "list_configs", "smoke_config",
]
