"""Assigned input-shape cells and ShapeDtypeStruct specs for the dry-run.

Four shapes per LM arch (seq_len x global_batch):
    train_4k    4,096 x 256    train_step
    prefill_32k 32,768 x 32    prefill_step (forward, cache build)
    decode_32k  32,768 x 128   serve_step (1 token, 32k cache)
    long_500k   524,288 x 1    serve_step (1 token, 500k cache) — only for
                               archs with sub-quadratic / bounded-cache
                               decode (cfg.long_context_ok)

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins — no
device allocation; the FULL configs are exercised only via lower/compile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def applicable_shapes(cfg) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.long_context_ok:
        out.append("long_500k")
    return out


def _tokens_spec(cfg, batch: int, seq: int) -> dict:
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def input_specs(cfg, shape_name: str) -> dict:
    """Specs for the *data* inputs of the step function of this cell."""
    s = SHAPES[shape_name]
    batch, seq = s["batch"], s["seq"]
    if s["kind"] == "train":
        spec = _tokens_spec(cfg, batch, seq)
        spec["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        return spec
    if s["kind"] == "prefill":
        return _tokens_spec(cfg, batch, seq)
    # decode: one new token against a seq-length cache
    spec = _tokens_spec(cfg, batch, 1)
    return spec


def cache_specs(cfg, shape_name: str, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode caches (via eval_shape; no alloc)."""
    from repro.models.model import init_caches
    s = SHAPES[shape_name]
    return jax.eval_shape(
        lambda: init_caches(cfg, s["batch"], s["seq"], dtype=dtype))
