"""LLaMA2-7B — the paper's primary evaluation target (Tbl. 2/3), included
as the reference arch for the quantization benchmarks [arXiv:2307.09288]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=32000,
)

SMOKE = ModelConfig(
    name="paper-llama2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab_size=512,
)
