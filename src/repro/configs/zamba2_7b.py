"""Zamba2-7B — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified]. 81 blocks total: every 6th position is an
application of the single shared attention block (13 applications, 68
Mamba2 layers). Constant SSM state + 13 bounded attn caches -> long_500k."""
from repro.models.config import ModelConfig

_KINDS = tuple(("attn" if i % 6 == 5 else "mamba") for i in range(81))

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    block_kinds=_KINDS, shared_attn_every=6,
    long_context_ok=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    block_kinds=tuple(("attn" if i % 6 == 5 else "mamba") for i in range(7)),
    shared_attn_every=6, long_context_ok=True,
)
