"""Qwen2-0.5B — dense GQA, QKV bias, tied embeddings [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=96, vocab_size=512, qkv_bias=True, tie_embeddings=True,
)
