"""Pallas TPU kernel: baseline MXFP4 dequant-GEMM (no metadata path).

Identical structure to m2xfp_matmul but decodes plain OCP MXFP4 weights
(codes + E8M0 scales only) — the hardware baseline the paper compares
against. Sharing the block structure makes the metadata path's marginal
cost directly measurable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitmath import exp2i
from .m2xfp_matmul import GROUP, _decode_codes, _expand_groups

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _mm_kernel(x_ref, wc_ref, ws_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mag, neg = _decode_codes(wc_ref[...], bk)
    scale = _expand_groups(
        exp2i(ws_ref[...].astype(jnp.int32) - 127), bk)
    w = (mag * scale)
    w = jnp.where(neg, -w, w).astype(jnp.bfloat16)
    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.bfloat16), w,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mxfp4_matmul_kernel(
    x: jax.Array,            # (M, K)
    w_codes: jax.Array,      # (K/2, N) u8
    w_scales: jax.Array,     # (K/32, N) u8
    *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    n = w_codes.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"mxfp4_matmul_kernel: blocks (bm={bm}, bn={bn}, bk={bk}) must "
            f"divide dims (m={m}, n={n}, k={k}); the grid would silently "
            f"drop the remainder tile — pad upstream (see ops._pad_rows)")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_codes, w_scales)
