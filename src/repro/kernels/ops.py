"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` for
correctness validation; on a TPU backend they compile through Mosaic.
Wrappers handle padding of the row dimension M (K and N must satisfy the
packed-layout alignment: K % 32 == 0, N % 128 == 0 for default blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layout import pack_w_mxfp4, pack_w_sgem, pack_x_elem_em
from .m2xfp_matmul import m2xfp_matmul_kernel, m2xfp_qmatmul_kernel
from .m2xfp_quantize import m2xfp_quantize_kernel
from .mxfp4_matmul import mxfp4_matmul_kernel

__all__ = [
    "on_tpu", "serve_block_m", "packed_matmul", "m2xfp_matmul",
    "m2xfp_qmatmul", "mxfp4_matmul", "m2xfp_quantize", "pack_w_sgem",
    "pack_w_mxfp4", "pack_x_elem_em",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, multiple: int):
    m = x.shape[0]
    pad = (-m) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def serve_block_m(m: int, cap: int = 128) -> int:
    """Row-block for a serve-path launch. Decode feeds B rows, chunked
    prefill up to B*chunk — round the live row count up to the 8-row
    sublane tile instead of padding every launch to the 128-row MXU block,
    so a 24-row prefill chunk pads to 24 rows, not 128. Row padding never
    changes live-row results (each output row depends only on its own
    input row), so this is a pure launch-shape choice."""
    if m >= cap:
        return cap
    return max(8, -(-m // 8) * 8)


def m2xfp_matmul(x: jax.Array, w_packed: dict, *,
                 block_m: int | None = None,
                 block_n: int = 128, block_k: int = 512) -> jax.Array:
    """x (M, K) @ Sg-EM-packed W (K, N) -> f32 (M, N).

    ``block_m=None`` picks the row block from M via ``serve_block_m``."""
    bm = serve_block_m(x.shape[0]) if block_m is None else block_m
    xp, m = _pad_rows(x, bm)
    out = m2xfp_matmul_kernel(
        xp, w_packed["codes"], w_packed["scales"], w_packed["meta"],
        bm=bm, bn=block_n, bk=block_k, interpret=not on_tpu())
    return out[:m]


def mxfp4_matmul(x: jax.Array, w_packed: dict, *,
                 block_m: int | None = None,
                 block_n: int = 128, block_k: int = 512) -> jax.Array:
    """x (M, K) @ MXFP4-packed W (K, N) -> f32 (M, N)."""
    bm = serve_block_m(x.shape[0]) if block_m is None else block_m
    xp, m = _pad_rows(x, bm)
    out = mxfp4_matmul_kernel(
        xp, w_packed["codes"], w_packed["scales"],
        bm=bm, bn=block_n, bk=block_k, interpret=not on_tpu())
    return out[:m]


def packed_matmul(x: jax.Array, w_packed: dict, fmt: str, **kw) -> jax.Array:
    """Codec-dispatched packed GEMM: x (M, K) @ ``fmt``-packed W -> f32.

    Thin registry front door over the per-codec kernels; raises for codecs
    without a Pallas kernel (e.g. nvfp4 serves through the XLA decode
    mirror — see repro.models.quant._serve_matmul)."""
    from repro.core.codecs import get_codec, kernel_codecs
    codec = get_codec(fmt)
    if codec.kernel is None:
        raise ValueError(
            f"codec {fmt!r} has no Pallas serve kernel; kernel-backed "
            f"codecs: {', '.join(kernel_codecs())}")
    return codec.kernel(x, w_packed, **kw)


def m2xfp_qmatmul(x_packed: dict, w_packed: dict, *, block_m: int = 128,
                  block_n: int = 128, block_k: int = 512) -> jax.Array:
    """Fully-packed W4A4 GEMM: Elem-EM X (K-major) @ Sg-EM W -> f32 (M, N)."""
    return m2xfp_qmatmul_kernel(
        x_packed["codes"], x_packed["scales"], x_packed["meta"],
        w_packed["codes"], w_packed["scales"], w_packed["meta"],
        bm=block_m, bn=block_n, bk=block_k, interpret=not on_tpu())


def m2xfp_quantize(x: jax.Array, *, block_m: int = 256,
                   block_k: int = 512) -> dict:
    """Online Elem-EM quantize of activations x (M, K) -> packed streams
    in K-major kernel layout (feeds m2xfp_qmatmul)."""
    codes, scales, meta = m2xfp_quantize_kernel(
        x.T, bm=block_m, bk=block_k, interpret=not on_tpu())
    return {"codes": codes, "scales": scales, "meta": meta}
