"""Mosaic-friendly bit arithmetic for FP4/FP6 code <-> value conversion.

Pallas TPU kernels cannot rely on gathers/LUTs or ``frexp``; these helpers
use only elementwise integer/float ops (bitcast, shifts, selects) that lower
to the VPU. They are the arithmetic equivalent of the paper's 16-entry
decode LUT (Fig. 10).

Code conventions (match core/):
  FP4 sign-magnitude: bit3 = sign, bits2..0 = E2M1 magnitude code
  E2M1 magnitude code c: c==0 -> 0, c==1 -> 0.5, else 2^((c>>1)-1)*(1+(c&1)/2)
  E2M3 magnitude code c: e=c>>3, m=c&7: e==0 -> m/8, else 2^(e-1)*(1+m/8)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "exp2i", "floor_log2_bits", "fp4_mag_from_code", "fp4_code_from_mag",
    "fp6_mag_from_code", "fp6_code_from_mag", "rtne_fp4", "rtne_fp6",
]


def exp2i(e: jax.Array) -> jax.Array:
    """2^e for integer e in [-126, 127], via exponent-field construction."""
    bits = (jnp.clip(e, -126, 127).astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def floor_log2_bits(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for normal positive f32 x, from the exponent field."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def fp4_mag_from_code(c: jax.Array) -> jax.Array:
    """E2M1 magnitude code (int, 0..7) -> grid value (f32)."""
    c = c.astype(jnp.int32)
    e = c >> 1
    m = (c & 1).astype(jnp.float32)
    normal = exp2i(e - 1) * (1.0 + 0.5 * m)
    return jnp.where(c == 0, 0.0, jnp.where(c == 1, 0.5, normal))


def fp4_code_from_mag(v: jax.Array) -> jax.Array:
    """Exact on-grid E2M1 magnitude -> code, from f32 bit fields."""
    v = v.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(v, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127           # true exponent
    m1 = (bits >> 22) & 1                     # top mantissa bit
    code = ((e + 1) << 1) | m1                # normals: v >= 1
    return jnp.where(v == 0.0, 0, jnp.where(v < 1.0, 1, code)).astype(jnp.int32)


def fp6_mag_from_code(c: jax.Array) -> jax.Array:
    """E2M3 magnitude code (int, 0..31) -> grid value (f32)."""
    c = c.astype(jnp.int32)
    e = c >> 3
    m = (c & 7).astype(jnp.float32)
    sub = m / 8.0
    normal = exp2i(e - 1) * (1.0 + m / 8.0)
    return jnp.where(e == 0, sub, normal)


def fp6_code_from_mag(v: jax.Array) -> jax.Array:
    """Exact on-grid E2M3 magnitude -> code, from f32 bit fields."""
    v = v.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(v, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    m3 = (bits >> 20) & 7                     # top 3 mantissa bits
    code = ((e + 1) << 3) | m3                # normals: v >= 1
    sub_code = (v * 8.0).astype(jnp.int32)    # subnormals: exact k/8
    return jnp.where(v < 1.0, sub_code, code).astype(jnp.int32)


def _rtne_grid(x: jax.Array, man_bits: int, emin: int, emax: int,
               maxval: float) -> jax.Array:
    """RTNE onto a mini-float grid using only VPU-friendly ops."""
    x = x.astype(jnp.float32)
    ax = jnp.abs(x)
    e = floor_log2_bits(jnp.maximum(ax, exp2i(jnp.full(ax.shape, emin, jnp.int32))))
    e = jnp.clip(e, emin, emax)
    step = exp2i(e - man_bits)
    q = jnp.round(ax / step) * step
    q = jnp.minimum(q, maxval)
    return jnp.sign(x) * q


def rtne_fp4(x: jax.Array) -> jax.Array:
    """RTNE to the E2M1 grid (saturating at +-6)."""
    return _rtne_grid(x, man_bits=1, emin=0, emax=2, maxval=6.0)


def rtne_fp6(x: jax.Array) -> jax.Array:
    """RTNE to the E2M3 grid (saturating at +-7.5)."""
    return _rtne_grid(x, man_bits=3, emin=0, emax=2, maxval=7.5)
