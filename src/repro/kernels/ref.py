"""Pure-jnp oracles for every Pallas kernel (no pallas, no bit tricks).

Each ref decodes the packed streams with the `core` reference machinery and
computes the GEMM in f32 via the same bf16 operand casting the kernels use,
so kernel-vs-ref comparisons are exact up to f32 accumulation order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dtypes import exp2int, fp4_code_to_value, fp6_code_to_value
from repro.core.m2xfp import elem_em_encode_parts
from repro.core.packing import group_reshape
from repro.core.scaling import shared_scale_exponent
from .layout import GROUP, N_SUB, SUBGROUP, interleave_unpack

__all__ = [
    "decode_w_sgem_ref", "decode_w_mxfp4_ref", "decode_x_elem_em_ref",
    "m2xfp_matmul_ref", "m2xfp_qmatmul_ref", "mxfp4_matmul_ref",
    "m2xfp_quantize_ref",
]


def _split_sign(codes: jax.Array):
    mag = fp4_code_to_value(codes & 7)
    sign = jnp.where((codes & 8) != 0, -1.0, 1.0)
    return mag, sign


def _expand(v: jax.Array, k: int) -> jax.Array:
    """(K/32, n) -> (K, n) repeating each group row."""
    n = v.shape[-1]
    return jnp.broadcast_to(v[:, None, :], (k // GROUP, GROUP, n)).reshape(k, n)


def decode_w_sgem_ref(packed: dict) -> jax.Array:
    """Sg-EM packed weight streams -> dense f32 (K, N)."""
    codes = interleave_unpack(packed["codes"])
    k, n = codes.shape
    mag, sign = _split_sign(codes)
    scale = _expand(exp2int(packed["scales"].astype(jnp.int32) - 127), k)
    meta = packed["meta"]
    fields = jnp.stack(
        [(meta >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.float32)                                  # (K/32, 4, N)
    mult = jnp.broadcast_to(
        fields[:, :, None, :], (k // GROUP, N_SUB, SUBGROUP, n)
    ).reshape(k, n) / 4.0 + 1.0
    return mag * sign * mult * scale


def decode_w_mxfp4_ref(packed: dict) -> jax.Array:
    codes = interleave_unpack(packed["codes"])
    k, _ = codes.shape
    mag, sign = _split_sign(codes)
    scale = _expand(exp2int(packed["scales"].astype(jnp.int32) - 127), k)
    return mag * sign * scale


def decode_x_elem_em_ref(packed: dict) -> jax.Array:
    """Elem-EM packed activation streams (K-major) -> dense f32 (M, K)."""
    codes = interleave_unpack(packed["codes"])             # (K, M)
    k, m = codes.shape
    mag, sign = _split_sign(codes)
    from repro.core.dtypes import fp4_value_to_code
    c4 = fp4_value_to_code(mag).reshape(k // GROUP, N_SUB, SUBGROUP, m)
    cmax = jnp.max(c4, axis=2, keepdims=True)
    top1 = (c4 == cmax) & (
        jnp.cumsum((c4 == cmax).astype(jnp.int32), axis=2) == 1)
    meta = packed["meta"]
    fields = jnp.stack(
        [(meta >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.int32)[:, :, None, :]                     # (K/32, 4, 1, M)
    c6 = jnp.maximum((cmax << 2) | fields, 1) - 1
    v6 = fp6_code_to_value(c6)
    vals = jnp.where(top1, jnp.broadcast_to(v6, c4.shape),
                     mag.reshape(c4.shape)).reshape(k, m)
    scale = _expand(exp2int(packed["scales"].astype(jnp.int32) - 127), k)
    return (vals * sign * scale).T                          # (M, K)


def _bf16_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)


def m2xfp_matmul_ref(x: jax.Array, w_packed: dict) -> jax.Array:
    return _bf16_matmul(x, decode_w_sgem_ref(w_packed))


def mxfp4_matmul_ref(x: jax.Array, w_packed: dict) -> jax.Array:
    return _bf16_matmul(x, decode_w_mxfp4_ref(w_packed))


def m2xfp_qmatmul_ref(x_packed: dict, w_packed: dict) -> jax.Array:
    return _bf16_matmul(decode_x_elem_em_ref(x_packed),
                        decode_w_sgem_ref(w_packed))


def m2xfp_quantize_ref(x_t: jax.Array) -> dict:
    """Oracle for the quantize kernel: (K, M) -> packed streams, via the
    core (LUT/searchsorted-based) Elem-EM encoder."""
    from .layout import pack_x_elem_em
    return pack_x_elem_em(x_t.T)
