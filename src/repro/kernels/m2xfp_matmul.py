"""Pallas TPU kernels: fused M2XFP dequant-GEMM.

TPU adaptation of the paper's augmented PE (Sec. 5.4): packed 4-bit operands
stream HBM -> VMEM, are decoded to bf16 in-register (exactly — every decoded
value has <= 6 significant bits so bf16 carries it losslessly), and hit the
MXU as a bf16 x bf16 -> f32 matmul. The subgroup scale refinement (1 + k/4)
and the E8M0 shared scale fold into the decode; the paper's shift-add PE
datapath is numerically identical.

Two entry points:
  * ``m2xfp_matmul_kernel``  — W packed (Sg-EM), X dense bf16 (serving path
    where activations were quantized by the quantize engine and dequantized
    on the fly — the common TPU deployment).
  * ``m2xfp_qmatmul_kernel`` — BOTH operands packed (full W4A4 datapath):
    X is Elem-EM with in-kernel top-1 re-identification (the Top-1 Decode
    Unit of Fig. 10, done as a vectorized max+first-match instead of a
    comparator tree).

Layouts: see layout.py (quantization axis K kept major for every packed
stream). Block shapes are (bm, bk) x (bk, bn) with bk a multiple of 32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitmath import exp2i, fp4_code_from_mag, fp4_mag_from_code, fp6_mag_from_code

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _decode_codes(codes_u8: jax.Array, bk: int):
    """u8 (bk/2, n) group-half-interleaved -> (mag f32 (bk, n), neg bool)."""
    n = codes_u8.shape[-1]
    pg = codes_u8.reshape(bk // GROUP, 16, n)
    lo = (pg & 0xF).astype(jnp.int32)
    hi = (pg >> 4).astype(jnp.int32)
    c = jnp.concatenate([lo, hi], axis=1).reshape(bk, n)   # natural K order
    mag = fp4_mag_from_code(c & 7)
    return mag, (c & 8) != 0


def _expand_groups(v: jax.Array, bk: int):
    """(bk/32, n) -> (bk, n) by repeating each group row 32x (major dim)."""
    n = v.shape[-1]
    return jnp.broadcast_to(v[:, None, :], (bk // GROUP, GROUP, n)).reshape(bk, n)


def _expand_subgroup_meta(meta_u8: jax.Array, bk: int):
    """u8 (bk/32, n) -> int32 (bk, n): 2-bit field of each row's subgroup."""
    n = meta_u8.shape[-1]
    fields = jnp.stack(
        [(meta_u8 >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.int32)                                     # (bk/32, 4, n)
    full = jnp.broadcast_to(
        fields[:, :, None, :], (bk // GROUP, N_SUB, SUBGROUP, n))
    return full.reshape(bk, n)


def _decode_w_sgem(wc_ref, ws_ref, wm_ref, bk: int) -> jax.Array:
    """Full Sg-EM weight decode -> bf16 (bk, bn)."""
    mag, neg = _decode_codes(wc_ref[...], bk)
    scale = _expand_groups(
        exp2i(ws_ref[...].astype(jnp.int32) - 127), bk)
    mult = 1.0 + _expand_subgroup_meta(wm_ref[...], bk).astype(jnp.float32) / 4.0
    w = mag * mult * scale
    return jnp.where(neg, -w, w).astype(jnp.bfloat16)


def _decode_x_elem_em(xc_ref, xs_ref, xm_ref, bk: int) -> jax.Array:
    """Elem-EM activation decode (K-major (bk, bm)) -> bf16 (bk, bm).

    Re-identifies the top-1 element per subgroup from the FP4 codes alone
    (lowest index on ties) and splices in the FP6 refinement — the Top-1
    Decode Unit."""
    bm = xc_ref.shape[-1]
    mag, neg = _decode_codes(xc_ref[...], bk)
    c4 = fp4_code_from_mag(mag)
    c4s = c4.reshape(bk // GROUP, N_SUB, SUBGROUP, bm)
    cmax = jnp.max(c4s, axis=2, keepdims=True)
    is_max = c4s == cmax
    first = jnp.cumsum(is_max.astype(jnp.int32), axis=2) == 1
    top1 = is_max & first                                    # lowest index tie
    meta = jnp.stack(
        [(xm_ref[...] >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.int32)[:, :, None, :]                       # (bk/32,4,1,bm)
    c6 = jnp.maximum((cmax << 2) | meta, 1) - 1
    v6 = fp6_mag_from_code(c6)
    vals = jnp.where(top1, jnp.broadcast_to(v6, c4s.shape),
                     mag.reshape(c4s.shape)).reshape(bk, bm)
    scale = _expand_groups(
        exp2i(xs_ref[...].astype(jnp.int32) - 127), bk)
    x = vals * scale
    return jnp.where(neg, -x, x).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _mm_w_kernel(x_ref, wc_ref, ws_ref, wm_ref, o_ref, *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _decode_w_sgem(wc_ref, ws_ref, wm_ref, bk)
    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.bfloat16), w,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += acc


def _mm_qq_kernel(xc_ref, xs_ref, xm_ref, wc_ref, ws_ref, wm_ref, o_ref,
                  *, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = _decode_x_elem_em(xc_ref, xs_ref, xm_ref, bk)      # (bk, bm)
    w = _decode_w_sgem(wc_ref, ws_ref, wm_ref, bk)         # (bk, bn)
    acc = jax.lax.dot_general(
        x, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += acc


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def m2xfp_matmul_kernel(
    x: jax.Array,            # (M, K) bf16/f32
    w_codes: jax.Array,      # (K/2, N) u8
    w_scales: jax.Array,     # (K/32, N) u8
    w_meta: jax.Array,       # (K/32, N) u8
    *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    n = w_codes.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"m2xfp_matmul_kernel: blocks (bm={bm}, bn={bn}, bk={bk}) must "
            f"divide dims (m={m}, n={n}, k={k}); the grid would silently "
            f"drop the remainder tile — pad upstream (see ops._pad_rows)")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_w_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_codes, w_scales, w_meta)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def m2xfp_qmatmul_kernel(
    x_codes: jax.Array,      # (K/2, M) u8
    x_scales: jax.Array,     # (K/32, M) u8
    x_meta: jax.Array,       # (K/32, M) u8
    w_codes: jax.Array,      # (K/2, N) u8
    w_scales: jax.Array,     # (K/32, N) u8
    w_meta: jax.Array,       # (K/32, N) u8
    *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    k = x_codes.shape[0] * 2
    m = x_codes.shape[1]
    n = w_codes.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"m2xfp_qmatmul_kernel: blocks (bm={bm}, bn={bn}, bk={bk}) must "
            f"divide dims (m={m}, n={n}, k={k}); the grid would silently "
            f"drop the remainder tile — pad upstream (see ops._pad_rows)")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_qq_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk // 2, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk // GROUP, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk // GROUP, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // GROUP, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x_codes, x_scales, x_meta, w_codes, w_scales, w_meta)
