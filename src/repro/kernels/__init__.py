# Pallas TPU kernels for the paper's compute hot-spots: fused dequant-GEMM
# (the augmented PE of Sec. 5.4) and the streaming quantization engine
# (Sec. 5.5). Validated in interpret mode against ref.py oracles.
# Layout helpers and constants are exported for docs/kernels.md.
from .layout import (  # noqa: F401
    GROUP, N_SUB, SUBGROUP, interleave_pack, interleave_unpack,
)
from .ops import (  # noqa: F401
    m2xfp_matmul, m2xfp_qmatmul, m2xfp_quantize, mxfp4_matmul, on_tpu,
    pack_w_mxfp4, pack_w_sgem, pack_x_elem_em, serve_block_m,
)

__all__ = [
    "GROUP", "N_SUB", "SUBGROUP", "interleave_pack", "interleave_unpack",
    "m2xfp_matmul", "m2xfp_qmatmul", "m2xfp_quantize", "mxfp4_matmul",
    "on_tpu", "pack_w_mxfp4", "pack_w_sgem", "pack_x_elem_em",
    "serve_block_m",
]
