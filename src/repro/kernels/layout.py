"""Kernel-side memory layouts (deploy-time transforms, pure jnp).

TPU Mosaic handles reshapes/reductions on *major* dims well but restricts
minor (lane) dim reshapes, so the kernels keep the quantization axis (the
GEMM contraction axis K) **major** for every packed operand:

  weights W (K, N):  codes u8 (K/2, N), scales u8 (K/32, N), meta u8 (K/32, N)
  activations X^T (K, M): same three streams with N -> M

Nibble pairing is *group-half interleaved*: within each group of 32 rows
along K, byte row ``g*16 + r`` holds the code of row ``g*32 + r`` (low
nibble) and row ``g*32 + 16 + r`` (high nibble). In-kernel decode then only
needs major-dim reshapes: (bk/2, n) -> (bk/32, 16, n) -> concat -> (bk, n).

Total footprint: 4 + 4/32 + 8/32 bits = 4.5 bits/element — identical EBW to
the paper's Sec. 5.2 layout, just a different (TPU-tiled) element order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dtypes import FP4_E2M1, FP8_E4M3, exp2int, round_to_grid
from repro.core.m2xfp import elem_em_encode_parts, sg_em_dequant_with_scale
from repro.core.packing import group_reshape
from repro.core.scaling import e8m0_encode, shared_scale_exponent

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

__all__ = [
    "GROUP", "SUBGROUP", "N_SUB",
    "pack_w_sgem", "pack_w_mxfp4", "pack_w_nvfp4", "pack_x_elem_em",
    "interleave_pack", "interleave_unpack",
]


def interleave_pack(codes: jax.Array) -> jax.Array:
    """Sign-magnitude 4-bit codes (K, n) -> u8 (K/2, n), group-half pairing."""
    k, n = codes.shape
    cg = codes.reshape(k // GROUP, GROUP, n).astype(jnp.uint8)
    lo = cg[:, :16, :]
    hi = cg[:, 16:, :]
    return ((lo & 0xF) | (hi << 4)).reshape(k // 2, n)


def interleave_unpack(packed: jax.Array) -> jax.Array:
    """u8 (K/2, n) -> int32 codes (K, n) (inverse of interleave_pack)."""
    k2, n = packed.shape
    pg = packed.reshape(k2 // 16, 16, n)
    lo = (pg & 0xF).astype(jnp.int32)
    hi = (pg >> 4).astype(jnp.int32)
    return jnp.concatenate([lo, hi], axis=1).reshape(2 * k2, n)


def _pack_meta_fields(fields: jax.Array) -> jax.Array:
    """2-bit fields (G, 4, n) -> u8 (G, n), subgroup j at bits 2j..2j+1."""
    f = fields.astype(jnp.uint32) & 0x3
    return (f[:, 0] | (f[:, 1] << 2) | (f[:, 2] << 4) | (f[:, 3] << 6)).astype(
        jnp.uint8)


def _sign_mag(values: jax.Array, negative: jax.Array) -> jax.Array:
    """FP4 grid values + sign mask -> 4-bit sign-magnitude codes."""
    from repro.core.dtypes import fp4_value_to_code
    mag = fp4_value_to_code(jnp.abs(values))
    return jnp.where(negative, mag | 8, mag).astype(jnp.int32)


def pack_w_sgem(w: jax.Array, adaptive: bool = True, rule: str = "floor"):
    """Sg-EM-2bit pack of weights (K, N), quantization groups along K.

    Returns dict(codes u8 (K/2,N), scales u8 (K/32,N), meta u8 (K/32,N)).
    """
    k, n = w.shape
    wt = w.astype(jnp.float32).T                       # (N, K), groups on last
    wg = group_reshape(wt, GROUP)                      # (N, K/32, 32)
    amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    _, k_sel, b_val = sg_em_dequant_with_scale(
        wg, s, SUBGROUP, bits=2, adaptive=adaptive, return_codes=True)
    e_stored = e[..., 0] + b_val                       # (N, K/32)
    s_final = (1.0 + k_sel.astype(jnp.float32) / 4.0) * \
        exp2int(e_stored)[..., None]                   # (N, K/32, 4)
    wsub = wg.reshape(n, k // GROUP, N_SUB, SUBGROUP)
    q = round_to_grid(wsub / s_final[..., None], FP4_E2M1)
    codes = _sign_mag(q, wsub < 0).reshape(n, k).T     # (K, N)
    meta = _pack_meta_fields(k_sel.transpose(1, 2, 0))  # (K/32, 4, N) -> (K/32, N)
    return {
        "codes": interleave_pack(codes),
        "scales": e8m0_encode(e_stored).T,             # (K/32, N)
        "meta": meta,                                  # (K/32, N)
    }


def pack_w_mxfp4(w: jax.Array, rule: str = "floor"):
    """Plain MXFP4 pack of weights (K, N) (baseline kernel operand)."""
    k, n = w.shape
    wt = w.astype(jnp.float32).T
    wg = group_reshape(wt, GROUP)
    amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    q = round_to_grid(wg / s, FP4_E2M1)
    codes = _sign_mag(q, wg < 0).reshape(n, k).T
    return {
        "codes": interleave_pack(codes),
        "scales": e8m0_encode(e[..., 0]).T,            # (K/32, N)
    }


def pack_w_nvfp4(w: jax.Array):
    """NVFP4 pack of weights (K, N): FP4 codes (group-half interleaved, so
    K % 32 == 0 like every packed operand), one E4M3 scale byte per group
    of 16 along K, and one f32 per-tensor scale.

    Returns dict(codes u8 (K/2,N), scales u8 (K/16,N), tscale f32 (1,1)).
    The scale math mirrors ``repro.core.formats.quantize_nvfp4`` exactly, so
    decode(pack(w)) == quantize_nvfp4(w-groups) bit-for-bit in f32.
    """
    k, n = w.shape
    wt = w.astype(jnp.float32).T                       # (N, K), groups on last
    xg = group_reshape(wt, 16)                         # (N, K/16, 16)
    amax_t = jnp.max(jnp.abs(wt))
    t = amax_t / (FP8_E4M3.max_value * FP4_E2M1.max_value)
    t = jnp.where(t == 0, 1.0, t)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s8 = round_to_grid(amax / (FP4_E2M1.max_value * t), FP8_E4M3)
    s = s8 * t
    s = jnp.where(s == 0, 1.0, s)
    q = round_to_grid(xg / s, FP4_E2M1)
    codes = _sign_mag(q, xg < 0).reshape(n, k).T       # (K, N)
    sbytes = jax.lax.bitcast_convert_type(             # e4m3 grid -> exact
        s8[..., 0].astype(jnp.float8_e4m3fn), jnp.uint8).T   # (K/16, N)
    return {
        "codes": interleave_pack(codes),
        "scales": sbytes,
        "tscale": t.reshape(1, 1),
    }


def pack_x_elem_em(x: jax.Array, rule: str = "floor"):
    """Elem-EM-top1 pack of activations (M, K) into K-major kernel layout.

    Returns dict(codes u8 (K/2,M), scales u8 (K/32,M), meta u8 (K/32,M)).
    """
    m, k = x.shape
    xg = group_reshape(x.astype(jnp.float32), GROUP)   # (M, K/32, 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    q4, _, _, meta, _ = elem_em_encode_parts(xg, s, SUBGROUP)
    codes = _sign_mag(q4, xg < 0).reshape(m, k).T      # (K, M)
    meta_b = _pack_meta_fields(meta.transpose(1, 2, 0))  # (K/32, 4, M) -> (K/32, M)
    return {
        "codes": interleave_pack(codes),
        "scales": e8m0_encode(e[..., 0]).T,            # (K/32, M)
        "meta": meta_b,                                # (K/32, M)
    }
