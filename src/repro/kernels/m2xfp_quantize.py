"""Pallas TPU kernel: the streaming M2XFP quantization engine (Sec. 5.5).

Online Elem-EM-top1 encode of activations, one VMEM-tile pass (the paper's
two-stage pipeline: scale + FP4/FP6 candidates, then top-1 select +
bias-clamp + pack). Input is K-major (K, M) so every group reduction and
reshape happens on major dims (see layout.py); outputs feed
``m2xfp_qmatmul_kernel`` directly.

Outputs per block: codes u8 (bk/2, bm), scales u8 (bk/32, bm),
meta u8 (bk/32, bm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitmath import (
    exp2i, floor_log2_bits, fp4_code_from_mag, fp6_code_from_mag,
    rtne_fp4, rtne_fp6,
)

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

DEFAULT_BM = 256
DEFAULT_BK = 512


def _quantize_kernel(x_ref, codes_ref, scales_ref, meta_ref, *, bk: int):
    bm = x_ref.shape[-1]
    xg = x_ref[...].astype(jnp.float32).reshape(bk // GROUP, GROUP, bm)

    # Stage 1 — shared scale (OCP floor rule) + FP4 baseline quantization.
    amax = jnp.max(jnp.abs(xg), axis=1, keepdims=True)          # (G, 1, bm)
    e = floor_log2_bits(jnp.maximum(amax, 1e-30)) - 2           # log2(amax/4)
    e = jnp.where(amax == 0, 0, e)
    e = jnp.clip(e, -127, 127)
    s = exp2i(e)
    xs = xg / s
    q4 = rtne_fp4(xs)                                           # FP4 values
    mag4 = jnp.abs(q4)
    c4 = fp4_code_from_mag(mag4)

    # Stage 2 — top-1 per subgroup (lowest index on ties), FP6 refine,
    # bias-clamp encode, pack.
    c4s = c4.reshape(bk // GROUP, N_SUB, SUBGROUP, bm)
    cmax = jnp.max(c4s, axis=2, keepdims=True)
    first = (c4s == cmax) & (
        jnp.cumsum((c4s == cmax).astype(jnp.int32), axis=2) == 1)
    xss = xs.reshape(c4s.shape)
    x_top = jnp.sum(jnp.where(first, xss, 0.0), axis=2)         # (G, 4, bm)
    c6 = fp6_code_from_mag(jnp.abs(rtne_fp6(x_top)))
    rmin = (cmax[..., 0, :] << 2)
    meta2 = jnp.clip(c6 + 1, rmin, rmin | 3) & 3                # (G, 4, bm)
    meta_byte = (
        meta2[:, 0].astype(jnp.uint32)
        | (meta2[:, 1].astype(jnp.uint32) << 2)
        | (meta2[:, 2].astype(jnp.uint32) << 4)
        | (meta2[:, 3].astype(jnp.uint32) << 6)
    ).astype(jnp.uint8)

    # sign-magnitude codes; keep the sign of values that rounded to zero
    sm = jnp.where(xg < 0, c4.reshape(xg.shape) | 8, c4.reshape(xg.shape))
    smg = sm.reshape(bk // GROUP, GROUP, bm).astype(jnp.uint8)
    packed = (smg[:, :16, :] & 0xF) | (smg[:, 16:, :] << 4)     # (G, 16, bm)

    codes_ref[...] = packed.reshape(bk // 2, bm)
    scales_ref[...] = (e[:, 0, :] + 127).astype(jnp.uint8)
    meta_ref[...] = meta_byte


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def m2xfp_quantize_kernel(
    x_t: jax.Array,  # (K, M) — activations transposed to K-major
    *,
    bm: int = DEFAULT_BM, bk: int = DEFAULT_BK, interpret: bool = True,
):
    k, m = x_t.shape
    bm, bk = min(bm, m), min(bk, k)
    if k % bk or m % bm:
        raise ValueError(
            f"m2xfp_quantize_kernel: blocks (bk={bk}, bm={bm}) must divide "
            f"dims (k={k}, m={m}); the grid would silently drop the "
            f"remainder tile — pad upstream (see ops._pad_rows)")
    grid = (k // bk, m // bm)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bk=bk),
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bm), lambda g, i: (g, i))],
        out_specs=[
            pl.BlockSpec((bk // 2, bm), lambda g, i: (g, i)),
            pl.BlockSpec((bk // GROUP, bm), lambda g, i: (g, i)),
            pl.BlockSpec((bk // GROUP, bm), lambda g, i: (g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k // 2, m), jnp.uint8),
            jax.ShapeDtypeStruct((k // GROUP, m), jnp.uint8),
            jax.ShapeDtypeStruct((k // GROUP, m), jnp.uint8),
        ],
        interpret=interpret,
    )(x_t)
