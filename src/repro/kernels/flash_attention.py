"""Pallas TPU flash attention (forward) — the §Perf memory-term fix.

The dry-run shows XLA-level chunked attention is memory-bound on
*materialized* probability tensors (e.g. ~1 TB/device/step for
olmoe train_4k): every (q_tile x kv_chunk) score/prob block round-trips
HBM. This kernel keeps scores, probabilities, and the running
(max, normalizer, accumulator) in VMEM scratch across the KV-chunk grid
axis — HBM traffic reduces to Q/K/V/O streams (the roofline-optimal
traffic), exactly like the paper keeps its PE datapath on-chip.

Causal + sliding-window masking via position operands; optional logit
softcap (gemma2). GQA: pass K/V already head-grouped (the wrapper repeats
per chunk — n_kv streams from HBM are the small ones).

Validated bit-for-bit reasonable (bf16 prob rounding) against the dense
oracle in interpret mode; targets Mosaic on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38
DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, pq_ref, pk_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, softcap, window, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # (bq, hd)
    k = k_ref[0]                                  # (bk, hd)
    v = v_ref[0]
    pq = pq_ref[...].reshape(-1)                  # (bq,)
    pk = pk_ref[...].reshape(-1)                  # (bk,)

    s = jax.lax.dot_general(
        q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (pk >= 0)[None, :] & (pq[:, None] >= pk[None, :]) & \
        (pq[:, None] - pk[None, :] < window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new

    @pl.when(kk == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "softcap", "window", "bq", "bk", "interpret"))
def flash_attention_kernel(
    q: jax.Array,      # (BH, Sq, hd) — batch*heads flattened
    k: jax.Array,      # (BH, Skv, hd) — heads already repeated for GQA
    v: jax.Array,      # (BH, Skv, hd)
    pos_q: jax.Array,  # (BH, Sq) int32
    pos_k: jax.Array,  # (BH, Skv) int32 (-1 = invalid)
    *, softcap=None, window: int = 1 << 30,
    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK, interpret: bool = True,
) -> jax.Array:
    bh, sq, hd = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    nq, nk = sq // bq, skv // bk
    grid = (bh, nq, nk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, softcap=softcap, window=window,
                          nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos_q, pos_k)
