"""Metrics registry: counters, gauges and fixed-bucket histograms with
labels, Prometheus-style text exposition, and a JSONL sink.

Dependency-free and thread-safe. Instrumentation across the repo is gated
by the ``REPRO_OBS`` environment variable (see :func:`enabled`); the
registry itself always works when called directly — the gating lives at
the instrumentation call sites so that with ``REPRO_OBS`` unset the hot
paths execute exactly the pre-instrumentation code (the tier-1
bit-identity test pins this for the serving engine).

``REPRO_OBS`` modes:
  unset / "" / "0"          everything off (the default; near-zero overhead)
  "1"                       every pillar on: metrics + trace + health
  "metrics,trace"           comma list of pillars to enable selectively
                            (pillars: ``metrics``, ``trace``, ``health``)

``REPRO_OBS_DIR``: when set, components that finish a unit of work (the
serving engine's ``run``, the benchmarks) drop ``metrics.jsonl`` +
``trace.json`` snapshots there (see ``repro.obs.autodump``).
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Dict, Iterable, Optional, Tuple

from repro.core import envflags

__all__ = [
    "PILLARS", "enabled", "obs_dir", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "registry", "counter", "gauge", "histogram",
    "DEFAULT_LATENCY_BUCKETS",
]

PILLARS = ("metrics", "trace", "health")

# Prometheus-style latency buckets (seconds); +Inf is implicit.
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

_MODE_CACHE: Dict[str, frozenset] = {}


def _modes(raw: str) -> frozenset:
    got = _MODE_CACHE.get(raw)
    if got is None:
        if raw == "1":
            got = frozenset(PILLARS)
        else:
            got = frozenset(
                m.strip() for m in raw.split(",") if m.strip())
            unknown = got - frozenset(PILLARS)
            if unknown:
                raise ValueError(
                    f"REPRO_OBS={raw!r}: unknown pillar(s) "
                    f"{sorted(unknown)}; valid: {PILLARS} or '1'")
        _MODE_CACHE[raw] = got
    return got


def enabled(pillar: str = "metrics") -> bool:
    """True when observability pillar ``pillar`` is on (env-driven, cheap
    enough to call on hot paths — one dict lookup when off)."""
    raw = envflags.get_raw("REPRO_OBS") or ""
    if raw in ("", "0"):
        return False
    return pillar in _modes(raw)


def obs_dir() -> Optional[str]:
    """Directory for metric/trace snapshots (``REPRO_OBS_DIR``), or None."""
    return envflags.get_str("REPRO_OBS_DIR") or None


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(key: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    """Base: one named metric holding samples keyed by label tuples."""

    kind = "untyped"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._samples: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def samples(self):
        with self._lock:
            return dict(self._samples)


class Counter(_Metric):
    """Monotonically increasing float, per label set."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Last-write-wins float, per label set."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._samples[_label_key(labels)] = float(value)

    def add(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(value)

    def value(self, **labels) -> float:
        return float(self._samples.get(_label_key(labels), 0.0))


class Histogram(_Metric):
    """Fixed-bucket cumulative histogram (Prometheus semantics: each
    bucket counts observations <= its upper bound; +Inf is implicit)."""

    kind = "histogram"

    def __init__(self, name, help, lock,
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, lock)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {self.name}: needs >= 1 bucket")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        v = float(value)
        with self._lock:
            s = self._samples.get(key)
            if s is None:
                s = {"counts": [0] * (len(self.buckets) + 1),
                     "sum": 0.0, "count": 0}
                self._samples[key] = s
            i = len(self.buckets)
            for j, b in enumerate(self.buckets):
                if v <= b:
                    i = j
                    break
            s["counts"][i] += 1
            s["sum"] += v
            s["count"] += 1

    def snapshot(self, **labels) -> dict:
        """Cumulative bucket counts {le: n} plus sum/count."""
        s = self._samples.get(_label_key(labels))
        if s is None:
            return {"buckets": {}, "sum": 0.0, "count": 0}
        return _hist_cumulative(self.buckets, s)


def _hist_cumulative(buckets, s) -> dict:
    out, acc = {}, 0
    for b, c in zip(buckets, s["counts"]):
        acc += c
        out[repr(float(b))] = acc
    out["+Inf"] = acc + s["counts"][-1]
    return {"buckets": out, "sum": s["sum"], "count": s["count"]}


class MetricsRegistry:
    """Get-or-create registry of named metrics."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self._lock, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self):
        with self._lock:
            return dict(self._metrics)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exposition --------------------------------------------------------

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines = []
        for name in sorted(self.metrics()):
            m = self._metrics[name]
            samples = m.samples()
            if not samples:
                continue
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key in sorted(samples):
                s = samples[key]
                if m.kind == "histogram":
                    cum = _hist_cumulative(m.buckets, s)
                    for le, n in cum["buckets"].items():
                        le_txt = le if le == "+Inf" else _fmt_float(le)
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(key, f'le={chr(34)}{le_txt}{chr(34)}')}"
                            f" {n}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)} {cum['sum']}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {cum['count']}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)} {s}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> list:
        """One JSON-able record per (metric, label set)."""
        now = time.time()
        out = []
        for name in sorted(self.metrics()):
            m = self._metrics[name]
            for key, s in sorted(m.samples().items()):
                rec = {"ts": now, "name": name, "type": m.kind,
                       "labels": dict(key)}
                if m.kind == "histogram":
                    rec.update(_hist_cumulative(m.buckets, s))
                else:
                    rec["value"] = s
                out.append(rec)
        return out

    def dump_jsonl(self, path: str, append: bool = True) -> int:
        """Append (default) one snapshot of every metric to ``path`` as
        JSON lines. Returns the number of records written."""
        recs = self.snapshot()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "a" if append else "w") as f:
            for rec in recs:
                f.write(json.dumps(rec) + "\n")
        return len(recs)


def _fmt_float(s: str) -> str:
    v = float(s)
    return str(int(v)) if math.isfinite(v) and v == int(v) else str(v)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every instrumentation site uses."""
    return _REGISTRY


def counter(name: str, help: str = "") -> Counter:
    return _REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS) -> Histogram:
    return _REGISTRY.histogram(name, help, buckets)
