"""Span tracer exporting Chrome-trace-format JSON.

``span(...)`` is a context manager over monotonic clocks
(``time.perf_counter_ns``); completed spans become ``ph: "X"`` events that
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) render as a
nested timeline per thread — nesting falls out of wall-clock containment
on the same tid, so ``serve.step`` > ``serve.phase.decode`` >
``serve.kernel.dispatch`` stack visually without parent bookkeeping.

Gated by the ``trace`` pillar of ``REPRO_OBS`` (registry.enabled): when
off, ``span`` yields without recording or reading the clock. Thread-safe:
events append under a lock; tids are real thread idents so concurrent
engine/trainer threads land on separate tracks.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import List, Optional

from .registry import enabled

__all__ = ["SpanTracer", "tracer", "span", "instant", "export_chrome_trace"]


class SpanTracer:
    """Accumulates Chrome trace events (X = complete span, i = instant)."""

    def __init__(self, process_name: str = "repro"):
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._pid = os.getpid()
        self.process_name = process_name

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "repro", **args):
        """Context manager timing one span. ``args`` (str/num values) show
        in the trace viewer's argument pane. No-op when the ``trace``
        pillar is off at entry."""
        if not enabled("trace"):
            yield
            return
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            t1 = time.perf_counter_ns()
            ev = {"name": name, "cat": cat, "ph": "X",
                  "ts": t0 / 1e3, "dur": (t1 - t0) / 1e3,
                  "pid": self._pid, "tid": threading.get_ident()}
            if args:
                ev["args"] = {k: v for k, v in args.items()}
            with self._lock:
                self._events.append(ev)

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Zero-duration marker (admissions, evictions, EOS hits)."""
        if not enabled("trace"):
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": time.perf_counter_ns() / 1e3,
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = {k: v for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()

    def export(self, path: str) -> int:
        """Write the full buffer as a Chrome trace JSON object. Returns the
        number of events written. The file loads directly in
        chrome://tracing or Perfetto."""
        evs = self.events()
        meta = [{"name": "process_name", "ph": "M", "pid": self._pid,
                 "tid": 0, "args": {"name": self.process_name}}]
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"traceEvents": meta + evs,
                       "displayTimeUnit": "ms"}, f)
        return len(evs)


_TRACER = SpanTracer()


def tracer() -> SpanTracer:
    """The process-global tracer."""
    return _TRACER


def span(name: str, cat: str = "repro", **args):
    return _TRACER.span(name, cat, **args)


def instant(name: str, cat: str = "repro", **args) -> None:
    _TRACER.instant(name, cat, **args)


def export_chrome_trace(path: str) -> int:
    return _TRACER.export(path)
