"""Quantization-health telemetry (the ``health`` pillar of ``REPRO_OBS``).

What the paper's accuracy claims hinge on is *observable* encoder
behaviour: how often elements clip against the FP4 grid, how often the
shared E8M0 scale saturates its representable range, which metadata modes
the encoders actually use, and whether pack -> decode -> re-pack drifts.
This module turns those into metrics:

* **In-jit probes** (:func:`probe_act`, :func:`drain_stats`) — tiny
  reductions traced into the serve-path GEMM / KV-encode graphs, shipped
  to the host with ``jax.debug.callback`` (asynchronous: the callback
  fires when the values are ready, nothing on the hot path blocks on it).
  Probes are gated *at trace time*: with the ``health`` pillar off the
  traced computation is byte-for-byte the uninstrumented graph.

* **Host-side sweeps** (:func:`weight_tree_health`) — per-layer clip
  rate, scale-byte saturation, metadata-mode histograms and re-encode
  drift over a packed parameter tree, computed once (e.g. at serving
  engine start) and recorded as per-layer gauges.

Metric names are documented in docs/observability.md.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from .registry import counter, enabled, gauge

__all__ = [
    "probe_act", "drain_stats", "weight_tree_health", "act_reencode_drift",
    "E8M0_BYTE_LOW", "E8M0_BYTE_HIGH",
]

# Biased E8M0 scale-byte bounds: repro.core.scaling clamps exponents to
# [-126, 127] -> stored bytes [1, 254]. A group whose scale byte sits at a
# bound had its exponent clipped — its elements may be misscaled.
E8M0_BYTE_LOW = 1
E8M0_BYTE_HIGH = 254

_FP4_MAX = 6.0          # FP4 E2M1 top grid value (|x|/s beyond it clips)
_FP4_TOP_CODE = 7       # magnitude code of the 6.0 grid point


def _site_counters(site: str, n, clipped, groups, sat_lo, sat_hi, meta):
    """Host-side accumulation of one probe's scalars into the registry."""
    counter("repro_quant_elems_total",
            "elements seen by quantization encoders").inc(float(n), site=site)
    counter("repro_quant_clipped_total",
            "elements clipped against the FP4 grid").inc(
        float(clipped), site=site)
    counter("repro_quant_groups_total",
            "scale groups seen by quantization encoders").inc(
        float(groups), site=site)
    counter("repro_quant_scale_saturated_total",
            "groups whose E8M0 scale byte hit a [1, 254] bound").inc(
        float(sat_lo), site=site, bound="low")
    counter("repro_quant_scale_saturated_total", "").inc(
        float(sat_hi), site=site, bound="high")
    mh = np.asarray(meta).reshape(-1)
    for code in range(mh.shape[0]):
        counter("repro_quant_meta_total",
                "metadata-mode occupancy (2-bit code histogram)").inc(
            float(mh[code]), site=site, code=str(code))
    elems = counter("repro_quant_elems_total").value(site=site)
    if elems > 0:
        gauge("repro_quant_clip_rate",
              "cumulative clipped / seen element fraction").set(
            counter("repro_quant_clipped_total").value(site=site) / elems,
            site=site, kind="online")


def drain_stats(site: str, stats: tuple) -> None:
    """`jax.debug.callback` target: ``stats`` is the scalar tuple built by
    a probe. Safe to call from any thread (registry is locked)."""
    _site_counters(site, *stats)


def probe_act(x, site: str) -> None:
    """Trace health reductions for an activation tensor about to be
    Elem-EM quantized (call INSIDE jit, before/independent of the encode —
    the probe recomputes the shared scale itself). No-op unless the
    ``health`` pillar is enabled at trace time."""
    if not enabled("health"):
        return
    import jax
    import jax.numpy as jnp
    from repro.core.m2xfp import elem_em_encode_parts
    from repro.core.packing import group_reshape
    from repro.core.scaling import shared_scale_exponent
    from repro.core.dtypes import exp2int

    xg = group_reshape(x.astype(jnp.float32), 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, "floor")
    s = exp2int(e)
    clipped = jnp.sum(jnp.abs(xg) > _FP4_MAX * s)
    sat_lo = jnp.sum(e <= E8M0_BYTE_LOW - 127)
    sat_hi = jnp.sum(e >= E8M0_BYTE_HIGH - 127)
    _, _, _, meta, _ = elem_em_encode_parts(xg, s, 8)
    hist = jnp.stack([jnp.sum(meta == c) for c in range(4)])
    stats = (jnp.asarray(x.size), clipped, jnp.asarray(e.size),
             sat_lo, sat_hi, hist)
    jax.debug.callback(partial(drain_stats, site), stats)


def probe_scaled(site: str, xs_over_s, e, meta_codes) -> None:
    """Probe variant for encoders that already hold the scaled values:
    ``xs_over_s`` = |x| / s per element, ``e`` integer scale exponents,
    ``meta_codes`` int 0..3 codes (any shape). Call INSIDE jit."""
    if not enabled("health"):
        return
    import jax
    import jax.numpy as jnp
    clipped = jnp.sum(jnp.abs(xs_over_s) > _FP4_MAX)
    sat_lo = jnp.sum(e <= E8M0_BYTE_LOW - 127)
    sat_hi = jnp.sum(e >= E8M0_BYTE_HIGH - 127)
    hist = jnp.stack([jnp.sum(meta_codes == c) for c in range(4)])
    stats = (jnp.asarray(xs_over_s.size), clipped, jnp.asarray(e.size),
             sat_lo, sat_hi, hist)
    jax.debug.callback(partial(drain_stats, site), stats)


# ---------------------------------------------------------------------------
# Host-side per-layer sweep over a packed parameter tree
# ---------------------------------------------------------------------------

def _leaf_paths(tree, is_leaf):
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _stream_stats(codes: np.ndarray, scales: np.ndarray,
                  meta: np.ndarray) -> dict:
    """Clip/saturation/meta stats straight from the packed u8 streams."""
    nibs = np.concatenate([codes & 0xF, codes >> 4], axis=None)
    mags = nibs & 7
    n = mags.size
    hist = np.bincount((np.concatenate(
        [(meta >> (2 * j)) & 0x3 for j in range(4)], axis=None)), minlength=4)
    return {
        "elems": int(n),
        "clip_rate": float(np.mean(mags == _FP4_TOP_CODE)),
        "groups": int(scales.size),
        "sat_low_rate": float(np.mean(scales <= E8M0_BYTE_LOW)),
        "sat_high_rate": float(np.mean(scales >= E8M0_BYTE_HIGH)),
        "meta_hist": hist.astype(int).tolist(),
    }


def _layer_drift(pw_cls, codes, scales, meta, shape) -> float:
    """Relative MSE between a decoded layer and its decode->repack->decode
    round trip (Sg-EM idempotence; ~0 means the packed checkpoint is a
    fixed point of the encoder)."""
    import jax.numpy as jnp
    from repro.models.quant import decode_serving_weight, pack_serving_weight
    w1 = decode_serving_weight(pw_cls(codes, scales, meta, shape))
    w2 = decode_serving_weight(pack_serving_weight(w1.astype(jnp.float32)))
    num = float(jnp.mean((w1.astype(jnp.float32) -
                          w2.astype(jnp.float32)) ** 2))
    den = float(jnp.mean(w1.astype(jnp.float32) ** 2)) + 1e-30
    return num / den


def weight_tree_health(tree, drift: bool = True) -> dict:
    """Sweep every ``PackedWeight`` leaf of a packed parameter tree and
    record per-layer gauges:

      repro_quant_clip_rate{layer,kind="weight"}      FP4 top-code occupancy
      repro_quant_scale_saturation_rate{layer,bound}  E8M0 bytes at 1 / 254
      repro_quant_meta_fraction{layer,code}           2-bit mode histogram
      repro_quant_reencode_drift{layer}               decode->repack rel. MSE

    Stacked (per-layer vmapped) leaves are reported per stacked index as
    ``<path>[i]``. Returns {layer: stats dict} (also useful standalone).
    Costs one decode (+ one repack when ``drift``) per layer — call it
    off the hot path (the serving engine does this once at startup)."""
    from repro.models.quant import PackedWeight
    report = {}
    leaves = _leaf_paths(
        tree, is_leaf=lambda x: isinstance(x, PackedWeight))
    for key, leaf in leaves:
        if not isinstance(leaf, PackedWeight):
            continue
        codes = np.asarray(leaf.codes)
        scales = np.asarray(leaf.scales)
        meta = np.asarray(leaf.meta)
        stacked = codes.ndim == len(leaf.shape) + 1
        layers = range(codes.shape[0]) if stacked else (None,)
        for i in layers:
            name = key if i is None else f"{key}[{i}]"
            c, s, m = ((codes[i], scales[i], meta[i]) if stacked
                       else (codes, scales, meta))
            st = _stream_stats(c, s, m)
            if drift:
                st["reencode_drift"] = _layer_drift(
                    PackedWeight, leaf.codes[i] if stacked else leaf.codes,
                    leaf.scales[i] if stacked else leaf.scales,
                    leaf.meta[i] if stacked else leaf.meta, leaf.shape)
            report[name] = st
            gauge("repro_quant_clip_rate",
                  "per-layer FP4 top-code occupancy of packed weights").set(
                st["clip_rate"], layer=name, kind="weight")
            gauge("repro_quant_scale_saturation_rate",
                  "per-layer fraction of E8M0 scale bytes at a bound").set(
                st["sat_low_rate"], layer=name, bound="low")
            gauge("repro_quant_scale_saturation_rate", "").set(
                st["sat_high_rate"], layer=name, bound="high")
            total = max(1, sum(st["meta_hist"]))
            for code, cnt in enumerate(st["meta_hist"]):
                gauge("repro_quant_meta_fraction",
                      "per-layer metadata-mode occupancy").set(
                    cnt / total, layer=name, code=str(code))
            if drift:
                gauge("repro_quant_reencode_drift",
                      "per-layer decode->repack relative MSE").set(
                    st["reencode_drift"], layer=name)
    return report


def act_reencode_drift(x) -> float:
    """Relative MSE of one Elem-EM fake-quant round trip applied twice —
    the activation-side idempotence check (host helper, not a hot-path
    probe)."""
    import jax.numpy as jnp
    from repro.core.m2xfp import quantize_act_m2xfp
    q1 = quantize_act_m2xfp(jnp.asarray(x, jnp.float32))
    q2 = quantize_act_m2xfp(q1)
    num = float(jnp.mean((q1 - q2) ** 2))
    den = float(jnp.mean(q1 ** 2)) + 1e-30
    return num / den
