"""Quantization-health telemetry (the ``health`` pillar of ``REPRO_OBS``).

What the paper's accuracy claims hinge on is *observable* encoder
behaviour: how often elements clip against the FP4 grid, how often the
shared scale byte saturates its representable range, which metadata modes
the encoders actually use, and whether pack -> decode -> re-pack drifts.
This module turns those into metrics, labeled by **codec name** (the
format registry in ``repro.core.codecs``) so multi-format serving can be
compared on one dashboard:

* **In-jit probes** (:func:`probe_act`, :func:`drain_stats`) — tiny
  reductions traced into the serve-path GEMM / KV-encode graphs, shipped
  to the host with ``jax.debug.callback`` (asynchronous: the callback
  fires when the values are ready, nothing on the hot path blocks on it).
  Probes are gated *at trace time*: with the ``health`` pillar off the
  traced computation is byte-for-byte the uninstrumented graph.

* **Host-side sweeps** (:func:`weight_tree_health`) — per-layer clip
  rate, scale-byte saturation, metadata-mode histograms and re-encode
  drift over a packed parameter tree, computed once (e.g. at serving
  engine start) and recorded as per-layer gauges.

Metric names are documented in docs/observability.md.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from .registry import counter, enabled, gauge

__all__ = [
    "probe_act", "drain_stats", "weight_tree_health", "act_reencode_drift",
    "E8M0_BYTE_LOW", "E8M0_BYTE_HIGH",
]

# Biased E8M0 scale-byte bounds: repro.core.scaling clamps exponents to
# [-126, 127] -> stored bytes [1, 254]. A group whose scale byte sits at a
# bound had its exponent clipped — its elements may be misscaled. (Codecs
# with other scale encodings carry their own bounds: Codec.scale_sat_bounds.)
E8M0_BYTE_LOW = 1
E8M0_BYTE_HIGH = 254

_FP4_MAX = 6.0          # FP4 E2M1 top grid value (|x|/s beyond it clips)
_FP4_TOP_CODE = 7       # magnitude code of the 6.0 grid point


def _site_counters(site: str, codec: str, n, clipped, groups, sat_lo,
                   sat_hi, meta):
    """Host-side accumulation of one probe's scalars into the registry."""
    counter("repro_quant_elems_total",
            "elements seen by quantization encoders").inc(
        float(n), site=site, codec=codec)
    counter("repro_quant_clipped_total",
            "elements clipped against the FP4 grid").inc(
        float(clipped), site=site, codec=codec)
    counter("repro_quant_groups_total",
            "scale groups seen by quantization encoders").inc(
        float(groups), site=site, codec=codec)
    counter("repro_quant_scale_saturated_total",
            "groups whose scale byte hit a representable-range bound").inc(
        float(sat_lo), site=site, codec=codec, bound="low")
    counter("repro_quant_scale_saturated_total", "").inc(
        float(sat_hi), site=site, codec=codec, bound="high")
    mh = np.asarray(meta).reshape(-1)
    for code in range(mh.shape[0]):
        counter("repro_quant_meta_total",
                "metadata-mode occupancy (2-bit code histogram)").inc(
            float(mh[code]), site=site, codec=codec, code=str(code))
    elems = counter("repro_quant_elems_total").value(site=site, codec=codec)
    if elems > 0:
        gauge("repro_quant_clip_rate",
              "cumulative clipped / seen element fraction").set(
            counter("repro_quant_clipped_total").value(
                site=site, codec=codec) / elems,
            site=site, codec=codec, kind="online")


def drain_stats(site: str, codec: str, stats: tuple) -> None:
    """`jax.debug.callback` target: ``stats`` is the scalar tuple built by
    a probe. Safe to call from any thread (registry is locked)."""
    _site_counters(site, codec, *stats)


def probe_act(x, site: str, codec: str = "m2xfp") -> None:
    """Trace health reductions for an activation tensor about to be
    quantized with ``codec`` (call INSIDE jit, before/independent of the
    encode — the probe recomputes the shared scale itself). No-op unless
    the ``health`` pillar is enabled at trace time; codecs without an E8M0
    shared scale skip the probe (their scale stats live in the weight
    sweep)."""
    if not enabled("health"):
        return
    from repro.core.codecs import get_codec
    cd = get_codec(codec)
    if cd.scale_kind != "e8m0":
        return
    import jax
    import jax.numpy as jnp
    from repro.core.packing import group_reshape
    from repro.core.scaling import shared_scale_exponent
    from repro.core.dtypes import exp2int

    xg = group_reshape(x.astype(jnp.float32), cd.group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, "floor")
    s = exp2int(e)
    clipped = jnp.sum(jnp.abs(xg) > _FP4_MAX * s)
    sat_lo = jnp.sum(e <= E8M0_BYTE_LOW - 127)
    sat_hi = jnp.sum(e >= E8M0_BYTE_HIGH - 127)
    if cd.has_meta:
        from repro.core.m2xfp import elem_em_encode_parts
        _, _, _, meta, _ = elem_em_encode_parts(xg, s, 8)
        hist = jnp.stack([jnp.sum(meta == c) for c in range(4)])
    else:
        hist = jnp.zeros((4,), jnp.int32)
    stats = (jnp.asarray(x.size), clipped, jnp.asarray(e.size),
             sat_lo, sat_hi, hist)
    jax.debug.callback(partial(drain_stats, site, codec), stats)  # reprolint: disable=undrained-callback -- drained by serve.guard.EngineGuard.drain (jax.effects_barrier) after every launch


def probe_scaled(site: str, xs_over_s, e, meta_codes=None,
                 codec: str = "m2xfp") -> None:
    """Probe variant for encoders that already hold the scaled values:
    ``xs_over_s`` = |x| / s per element, ``e`` integer scale exponents,
    ``meta_codes`` int 0..3 codes (any shape; None for metadata-free
    codecs). Call INSIDE jit."""
    if not enabled("health"):
        return
    import jax
    import jax.numpy as jnp
    clipped = jnp.sum(jnp.abs(xs_over_s) > _FP4_MAX)
    sat_lo = jnp.sum(e <= E8M0_BYTE_LOW - 127)
    sat_hi = jnp.sum(e >= E8M0_BYTE_HIGH - 127)
    if meta_codes is None:
        hist = jnp.zeros((4,), jnp.int32)
    else:
        hist = jnp.stack([jnp.sum(meta_codes == c) for c in range(4)])
    stats = (jnp.asarray(xs_over_s.size), clipped, jnp.asarray(e.size),
             sat_lo, sat_hi, hist)
    jax.debug.callback(partial(drain_stats, site, codec), stats)  # reprolint: disable=undrained-callback -- drained by serve.guard.EngineGuard.drain (jax.effects_barrier) after every launch


# ---------------------------------------------------------------------------
# Host-side per-layer sweep over a packed parameter tree
# ---------------------------------------------------------------------------

def _leaf_paths(tree, is_leaf):
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _stream_stats(streams: dict, codec) -> dict:
    """Clip/saturation/meta stats straight from the packed u8 streams."""
    codes = np.asarray(streams["codes"])
    nibs = np.concatenate([codes & 0xF, codes >> 4], axis=None)
    mags = nibs & 7
    st = {
        "elems": int(mags.size),
        "clip_rate": float(np.mean(mags == _FP4_TOP_CODE)),
    }
    scales = np.asarray(streams.get("scales")) \
        if "scales" in streams else None
    if scales is not None and codec.scale_sat_bounds is not None:
        lo, hi = codec.scale_sat_bounds
        st["groups"] = int(scales.size)
        st["sat_low_rate"] = float(np.mean(scales <= lo))
        st["sat_high_rate"] = float(np.mean(scales >= hi))
    else:
        st["groups"] = int(scales.size) if scales is not None else 0
        st["sat_low_rate"] = 0.0
        st["sat_high_rate"] = 0.0
    if codec.has_meta and "meta" in streams:
        meta = np.asarray(streams["meta"])
        st["meta_hist"] = np.bincount(np.concatenate(
            [(meta >> (2 * j)) & 0x3 for j in range(4)], axis=None),
            minlength=4).astype(int).tolist()
    else:
        st["meta_hist"] = [0, 0, 0, 0]
    return st


def _layer_drift(leaf) -> float:
    """Relative MSE between a decoded layer and its decode->repack->decode
    round trip (encoder idempotence; ~0 means the packed checkpoint is a
    fixed point of the encoder)."""
    import jax.numpy as jnp
    from repro.models.quant import decode_serving_weight, pack_serving_weight
    w1 = decode_serving_weight(leaf, dtype=jnp.float32)
    w2 = decode_serving_weight(
        pack_serving_weight(w1, leaf.codec), dtype=jnp.float32)
    num = float(jnp.mean((w1 - w2) ** 2))
    den = float(jnp.mean(w1 ** 2)) + 1e-30
    return num / den


def weight_tree_health(tree, drift: bool = True) -> dict:
    """Sweep every ``PackedTensor`` leaf of a packed parameter tree and
    record per-layer gauges (labeled by the leaf's codec):

      repro_quant_clip_rate{layer,codec,kind="weight"}  FP4 top-code occupancy
      repro_quant_scale_saturation_rate{layer,codec,bound}  scale bytes at a
                                                        representable bound
      repro_quant_meta_fraction{layer,codec,code}       2-bit mode histogram
      repro_quant_reencode_drift{layer,codec}           decode->repack rel. MSE

    Stacked (per-layer vmapped) leaves are reported per stacked index as
    ``<path>[i]``. Returns {layer: stats dict} (also useful standalone).
    Costs one decode (+ one repack when ``drift``) per layer — call it
    off the hot path (the serving engine does this once at startup)."""
    from repro.core.codecs import PackedTensor, get_codec
    from repro.models.quant import PackedWeight  # noqa: F401 (same class)
    report = {}
    leaves = _leaf_paths(
        tree, is_leaf=lambda x: isinstance(x, PackedTensor))
    for key, leaf in leaves:
        if not isinstance(leaf, PackedTensor):
            continue
        codec = get_codec(leaf.codec)
        arrays = {name: np.asarray(s) for name, s in leaf.streams.items()}
        stacked = arrays["codes"].ndim == len(leaf.shape) + 1
        layers = range(arrays["codes"].shape[0]) if stacked else (None,)
        for i in layers:
            name = key if i is None else f"{key}[{i}]"
            streams_i = ({n: a[i] for n, a in arrays.items()} if stacked
                         else arrays)
            st = _stream_stats(streams_i, codec)
            st["codec"] = codec.name
            if drift:
                st["reencode_drift"] = _layer_drift(PackedTensor(
                    {n: (leaf.streams[n][i] if stacked else leaf.streams[n])
                     for n in leaf.streams}, leaf.shape, leaf.codec))
            report[name] = st
            gauge("repro_quant_clip_rate",
                  "per-layer FP4 top-code occupancy of packed weights").set(
                st["clip_rate"], layer=name, codec=codec.name, kind="weight")
            gauge("repro_quant_scale_saturation_rate",
                  "per-layer fraction of scale bytes at a bound").set(
                st["sat_low_rate"], layer=name, codec=codec.name, bound="low")
            gauge("repro_quant_scale_saturation_rate", "").set(
                st["sat_high_rate"], layer=name, codec=codec.name,
                bound="high")
            total = max(1, sum(st["meta_hist"]))
            for code, cnt in enumerate(st["meta_hist"]):
                gauge("repro_quant_meta_fraction",
                      "per-layer metadata-mode occupancy").set(
                    cnt / total, layer=name, codec=codec.name,
                    code=str(code))
            if drift:
                gauge("repro_quant_reencode_drift",
                      "per-layer decode->repack relative MSE").set(
                    st["reencode_drift"], layer=name, codec=codec.name)
    return report


def act_reencode_drift(x, fmt: str = "m2xfp") -> float:
    """Relative MSE of one activation fake-quant round trip applied twice —
    the activation-side idempotence check (host helper, not a hot-path
    probe)."""
    import jax.numpy as jnp
    from repro.core.codecs import get_codec
    fq = get_codec(fmt).fake_quant_act
    q1 = fq(jnp.asarray(x, jnp.float32))
    q2 = fq(q1)
    num = float(jnp.mean((q1 - q2) ** 2))
    den = float(jnp.mean(q1 ** 2)) + 1e-30
    return num / den
