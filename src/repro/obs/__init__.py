# Unified telemetry layer (docs/observability.md): metrics registry with
# Prometheus text exposition + JSONL sink, Chrome-trace span tracer, and
# quantization-health probes. Everything is gated by REPRO_OBS — with it
# unset, every instrumentation site is a no-op and the serve path stays
# bit-identical to an uninstrumented build (pinned by tests/test_obs.py).
from .registry import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS, Counter, Gauge, Histogram, MetricsRegistry,
    PILLARS, counter, enabled, gauge, histogram, obs_dir, registry,
)
from .tracing import (  # noqa: F401
    SpanTracer, export_chrome_trace, instant, span, tracer,
)
from . import quant_health  # noqa: F401

__all__ = [
    "enabled", "obs_dir", "registry", "counter", "gauge", "histogram",
    "tracer", "span", "instant", "export_chrome_trace", "quant_health",
    "dump", "autodump", "reset",
]


def dump(directory: str) -> dict:
    """Write a metric snapshot (append) and the full trace buffer into
    ``directory`` as ``metrics.jsonl`` + ``trace.json``. Returns the paths."""
    import os
    os.makedirs(directory, exist_ok=True)
    metrics = os.path.join(directory, "metrics.jsonl")
    trace = os.path.join(directory, "trace.json")
    registry().dump_jsonl(metrics)
    export_chrome_trace(trace)
    return {"metrics": metrics, "trace": trace}


def autodump() -> dict:
    """``dump`` into ``REPRO_OBS_DIR`` if set and any pillar is enabled;
    components call this when a unit of work drains (engine.run, benches)."""
    d = obs_dir()
    if d and any(enabled(p) for p in PILLARS):
        return dump(d)
    return {}


def reset() -> None:
    """Clear registry and tracer (test isolation)."""
    registry().reset()
    tracer().reset()
