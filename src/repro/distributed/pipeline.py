"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

For depth-dominated models (mixtral's 56 layers, zamba2's 81) a third
parallel dimension beyond FSDP×TP lets the fleet scale past the point where
TP collectives saturate ICI: stages hold contiguous layer blocks, and
microbatches stream through a `collective_permute` ring. The schedule is
the classic GPipe fill-drain: T = n_micro + n_stages - 1 ticks, bubble
fraction (n_stages-1)/T.

Implementation: `shard_map` over the 'pipe' axis (all other mesh axes stay
auto-sharded, so FSDP/TP compose inside each stage), `lax.scan` over ticks,
`jax.lax.ppermute` to hand activations to the next stage. Outputs are
collected on the last stage and psum-broadcast back (cheap relative to the
stage compute; avoidable with a sharded-output variant).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, stage_params, x: jax.Array,
                   mesh, n_stages: int, axis: str = "pipe") -> jax.Array:
    """Run ``stage_fn(params_i, h) -> h`` over ``n_stages`` pipeline stages.

    stage_params: pytree with leading dim n_stages (stage i's params).
    x: (n_micro, mb, ...) microbatched input. Returns (n_micro, mb, ...)
    outputs after all stages."""
    from jax.sharding import PartitionSpec as P
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    def shard_body(params, xm):
        # params: (1, ...) slice for this stage; xm: full microbatches
        params = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            h_prev = carry                       # from upstream last tick
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            mb = jax.lax.dynamic_index_in_dim(xm, mb_idx, 0, keepdims=False)
            h_in = jnp.where(idx == 0, mb, h_prev)
            h_out = stage_fn(params, h_in)
            h_next = jax.lax.ppermute(h_out, axis, fwd_perm)
            return h_next, h_out

        h0 = jnp.zeros_like(x[0])
        _, outs = jax.lax.scan(tick, h0, jnp.arange(ticks))
        # last stage's outputs for ticks [n_stages-1, ticks) are the results
        result = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, n_micro, 0)
        # broadcast the last stage's results to every stage (keeps the
        # output replicated over 'pipe'; callers on any shard see it)
        result = jnp.where(idx == n_stages - 1, result, jnp.zeros_like(result))
        return jax.lax.psum(result, axis)

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)
    # fully-manual shard_map: inputs replicated over the non-pipe axes
    # (stage_fn may itself run sharded compute via nested jit on TPU pods;
    # the fill-drain schedule is axis-local either way)
    return jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(p_spec, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x)
