"""Straggler detection and preemption handling.

At pod scale, a single slow chip/host gates every synchronous collective.
The monitor tracks per-step wall time with an EWMA + MAD band; sustained
outliers trigger a policy callback (log -> checkpoint -> request re-shard).
Preemption (SIGTERM from the cluster scheduler) flips a flag the train loop
checks each step, guaranteeing a final checkpoint before exit.
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional

__all__ = ["StragglerMonitor", "PreemptionGuard"]


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.9,
                 patience: int = 3,
                 on_straggle: Optional[Callable[[int, float], None]] = None):
        self.threshold = threshold
        self.alpha = ewma
        self.patience = patience
        self.on_straggle = on_straggle
        self.mean: Optional[float] = None
        self.slow_streak = 0
        self.events: list[tuple[int, float]] = []
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        """Returns True if this step was flagged as a straggler event."""
        dt = time.monotonic() - self._t0
        if self.mean is None:
            self.mean = dt
            return False
        slow = dt > self.threshold * self.mean
        # EWMA excludes outliers so one straggler doesn't poison the baseline
        if not slow:
            self.mean = self.alpha * self.mean + (1 - self.alpha) * dt
            self.slow_streak = 0
            return False
        self.slow_streak += 1
        if self.slow_streak >= self.patience:
            self.events.append((step, dt))
            if self.on_straggle:
                self.on_straggle(step, dt)
            self.slow_streak = 0
            return True
        return False


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)
