"""Logical-axis sharding (MaxText-style) over the production mesh.

Model code annotates activations with *logical* axis names via
``constrain``; a rules table maps logical names to mesh axes. Outside a
``use_sharding`` context every call is a no-op, so the same model code runs
single-device tests and 512-chip dry-runs unchanged.

Default rules (see DESIGN.md Sec. 5):
  batch    -> ('pod', 'data')   pure DP across pods, DP within pod
  kv_seq   -> 'data' only in context-parallel serving (long_500k)
  heads/kv_heads/mlp/vocab/expert_mlp -> 'model'   (tensor parallelism)
  embed    -> None for activations
  fsdp     -> 'data'            weight & optimizer-state sharding
  expert   -> 'data'            expert parallelism when E % data == 0
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES", "use_sharding", "constrain", "logical_to_spec",
    "named_sharding", "active_mesh", "current_rules",
]

_state = threading.local()

DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",          # decode caches: sequence-sharded over TP
                                # (long_500k overrides to ('data','model'))
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "q_dim": "model",           # fused head*hd projections
    "kv_dim": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "data",           # EP over the data axis (when divisible)
    "expert_mlp": "model",
    "fsdp": "data",             # weight-shard (ZeRO-3) axis
    "conv": None,
    "state": None,
    # sequence parallelism for the residual stream between blocks: set to
    # 'model' (perf lever) to turn TP activation all-reduces into
    # reduce-scatter + all-gather pairs (half the wire bytes)
    "seq_sp": None,
    # decode caches keep their own batch axis so weight-stationary decode
    # sharding (batch->None for activations) can still shard the cache
    "cache_batch": ("pod", "data"),
}

# activations tolerate GSPMD padding up to this blow-up factor (e.g. 40
# heads over 16-way TP pads to 48 = 1.2x); weights/state never pad.
_PAD_WASTE_LIMIT = 1.5


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> dict:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[dict] = None):
    """Install a mesh + logical rules for the enclosed trace."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)
    def _filter(v):
        if v is None:
            return None
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh.axis_names)
            return kept or None
        return v if v in mesh.axis_names else None
    merged = {k: _filter(v) for k, v in merged.items()}
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", None)
    _state.mesh, _state.rules = mesh, merged
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev_mesh, prev_rules


def logical_to_spec(axes, shape=None, rules=None, allow_pad=False) -> P:
    """Tuple of logical axis names (or None) -> PartitionSpec.

    If ``shape`` is given, drop shardings that don't divide the dim.
    ``allow_pad`` (activations): keep non-dividing shardings when the GSPMD
    padding waste stays under _PAD_WASTE_LIMIT (e.g. 40 heads over 16-way
    TP -> 48, 1.2x); 2 kv-heads over 16 (8x) is dropped either way."""
    rules = rules or current_rules()
    mesh = active_mesh()
    out = []
    for i, a in enumerate(axes):
        v = rules.get(a) if a else None
        if v is not None and shape is not None and mesh is not None:
            size = 1
            for ax in ((v,) if isinstance(v, str) else v):
                size *= mesh.shape[ax]
            if shape[i] % size != 0:
                d = shape[i]
                waste = (-(-d // size) * size) / d
                if not (allow_pad and waste <= _PAD_WASTE_LIMIT):
                    v = None
        out.append(v)
    # PartitionSpec forbids using a mesh axis twice
    seen: set = set()
    cleaned = []
    for v in out:
        axes_v = (v,) if isinstance(v, str) else (v or ())
        if any(a in seen for a in axes_v):
            cleaned.append(None)
        else:
            seen.update(axes_v)
            cleaned.append(v)
    return P(*cleaned)


def named_sharding(axes, shape=None) -> Optional[NamedSharding]:
    mesh = active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes, shape))


def constrain(x: jax.Array, axes) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a mesh)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, allow_pad=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter / cache logical axes (path-name driven)
# ---------------------------------------------------------------------------

_STACKED_GROUPS = ("layers", "mlstm", "slstm", "mamba")

_NAME_AXES = {
    # attention projections (D, H*hd) etc.
    "wq": ("fsdp", "q_dim"), "wk": ("fsdp", "kv_dim"), "wv": ("fsdp", "kv_dim"),
    "wo": ("q_dim", "fsdp"),
    "bq": ("q_dim",), "bk": ("kv_dim",), "bv": ("kv_dim",),
    # dense mlp
    "gate": ("fsdp", "mlp"), "up": ("fsdp", "mlp"), "down": ("mlp", "fsdp"),
    # ssm / xlstm projections
    "in_proj": ("fsdp", "mlp"), "out_proj": ("mlp", "fsdp"),
    "w": ("fsdp", "mlp"), "ff_up": ("fsdp", "mlp"), "ff_down": ("mlp", "fsdp"),
    "w_o": ("fsdp", "mlp"), "w_if": ("fsdp", None),
    "router": ("fsdp", None),
    # embeddings
    "embed": ("vocab", "fsdp"), "lm_head": ("fsdp", "vocab"),
}

_MOE_AXES = {  # expert weights (E, K, N)
    "gate": ("expert", "fsdp", "expert_mlp"),
    "up": ("expert", "fsdp", "expert_mlp"),
    "down": ("expert", "expert_mlp", "fsdp"),
}

_MLSTM_BLOCKDIAG = ("wq", "wk", "wv")     # (H, P, P) under 'mlstm'


def infer_logical_axes(path_names: tuple, shape: tuple) -> tuple:
    """Logical axes tuple for a parameter leaf given its key path + shape."""
    name = path_names[-1] if path_names else ""
    stacked = int(any(k in _STACKED_GROUPS for k in path_names))
    base_ndim = len(shape) - stacked
    if "mlstm" in path_names and name in _MLSTM_BLOCKDIAG:
        axes = ("heads", None, None)
    elif "ffn" in path_names and name in _MOE_AXES and base_ndim == 3:
        axes = _MOE_AXES[name]
    elif name in _NAME_AXES and base_ndim == len(_NAME_AXES[name]):
        axes = _NAME_AXES[name]
    else:
        axes = (None,) * base_ndim
    return (None,) * stacked + axes


def _path_names(path) -> tuple:
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return tuple(out)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    """NamedSharding tree matching ``params`` (PackedWeight-aware: its
    streams inherit the parent weight's axes)."""
    with use_sharding(mesh, rules):
        def leaf_sharding(path, leaf):
            names = _path_names(path)
            # PackedTensor children end in codes/scales/meta/tscale
            if names and names[-1] in ("codes", "scales", "meta", "tscale"):
                names = names[:-1]
            axes = infer_logical_axes(names, leaf.shape)
            return NamedSharding(mesh, logical_to_spec(axes, leaf.shape))

        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        tdef = jax.tree_util.tree_structure(params)
        return jax.tree_util.tree_unflatten(
            tdef, [leaf_sharding(p, l) for p, l in flat])


_CACHE_AXES = {
    "k": (None, "cache_batch", "kv_seq", "kv_heads", None),
    "v": (None, "cache_batch", "kv_seq", "kv_heads", None),
    "pos": (None, None),
    "ssm": (None, "cache_batch", "heads", None, None),
    "conv": (None, "cache_batch", None, None),
    "C": (None, "cache_batch", "heads", None, None),
    "n": (None, "cache_batch", "heads", None),
    "m": (None, "cache_batch", "heads"),
    "c": (None, "cache_batch", None),
    "h": (None, "cache_batch", None),
}


def cache_shardings(caches, mesh: Mesh, rules: Optional[dict] = None):
    """NamedSharding tree for decode caches (leaves stacked over layers)."""
    with use_sharding(mesh, rules):
        def leaf_sharding(path, leaf):
            names = _path_names(path)
            name = names[-1] if names else ""
            if name in ("codes", "scales", "meta", "tscale") \
                    and len(names) >= 2:
                name = names[-2]            # quantized KV streams -> k/v axes
            axes = _CACHE_AXES.get(name, (None,) * leaf.ndim)
            axes = axes[:leaf.ndim]
            if len(axes) < leaf.ndim:
                axes = axes + (None,) * (leaf.ndim - len(axes))
            return NamedSharding(mesh, logical_to_spec(axes, leaf.shape))

        flat = jax.tree_util.tree_flatten_with_path(caches)[0]
        tdef = jax.tree_util.tree_structure(caches)
        return jax.tree_util.tree_unflatten(
            tdef, [leaf_sharding(p, l) for p, l in flat])
