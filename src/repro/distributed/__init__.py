# Distribution substrate: logical sharding rules, context-parallel decode,
# pipeline parallelism, gradient compression, straggler/elastic handling.
