"""AdamW + LR schedules + global-norm clipping, from scratch (no optax).

Layout convention: params stored f32 ("master"); the forward pass casts
matrix leaves to bf16 (see trainer). Optimizer state m/v are f32, sharded
identically to their parameters (ZeRO-3 falls out of the param shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
    "warmup_cosine", "global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def warmup_cosine(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        t = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm,
                                  0.1 + 0.9 * cos)
    return sched


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, opt_state, cfg: AdamWConfig,
                 schedule=None):
    """One AdamW step. Decoupled weight decay on matrix (ndim >= 2) leaves.
    Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = (schedule or warmup_cosine(cfg))(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
