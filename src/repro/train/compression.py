"""Gradient compression for the cross-pod all-reduce.

Two composable stages with error feedback (residual accumulation):
  * top-k sparsification (keep the k largest-|g| entries per leaf)
  * int8 quantization (symmetric per-leaf scale)

At 1000+ node scale the inter-pod links are the slowest hop (DCN or
long-haul ICI); compressing only the *cross-pod* reduction keeps in-pod
gradients exact while cutting the slow-link traffic by
(32/8 = 4x for int8) * (1/density for top-k). Error feedback makes the
scheme unbiased-in-the-limit: what a step drops is re-injected next step.

Integration: trainer.py runs the model under shard_map(auto={data, model})
over the 'pod' axis; per-pod gradients are compressed, psum'd across pods,
and decompressed (see make_train_step).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "init_error_feedback", "compress_decompress",
           "compressed_psum"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    int8: bool = True
    topk_density: float = 1.0       # 1.0 = no sparsification
    axis: str = "pod"               # mesh axis carrying the slow links


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _topk_mask(x: jax.Array, density: float) -> jax.Array:
    if density >= 1.0:
        return jnp.ones_like(x, dtype=bool)
    flat = jnp.abs(x).reshape(-1)
    k = max(1, int(flat.shape[0] * density))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.abs(x) >= thresh


def compress_decompress(g: jax.Array, err: jax.Array, cfg: CompressionConfig):
    """Single-leaf compress->decompress with error feedback. Returns
    (decompressed, new_err). Used for numerics tests and the psum path."""
    x = g.astype(jnp.float32) + err
    mask = _topk_mask(x, cfg.topk_density)
    kept = jnp.where(mask, x, 0.0)
    if cfg.int8:
        q, s = _quant_int8(kept)
        deq = q.astype(jnp.float32) * s
    else:
        deq = kept
    return deq, x - deq


def compressed_psum(grads, err_state, cfg: CompressionConfig,
                    axis_name: str, n_pods: int):
    """Cross-pod mean of gradients with compression + error feedback.

    Runs inside shard_map over ``axis_name``. int8 payloads are summed as
    int32 (exact for <= 2^23 pods) and rescaled with a max-reduced scale.
    """
    def one(g, err):
        x = g.astype(jnp.float32) + err
        mask = _topk_mask(x, cfg.topk_density)
        kept = jnp.where(mask, x, 0.0)
        if cfg.int8:
            # shared scale across pods so the int8 sum is well-defined
            local_amax = jnp.max(jnp.abs(kept))
            amax = jax.lax.pmax(local_amax, axis_name)
            scale = jnp.where(amax == 0, 1.0, amax / 127.0)
            q = jnp.clip(jnp.round(kept / scale), -127, 127).astype(jnp.int8)
            summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
            reduced = summed.astype(jnp.float32) * scale / n_pods
            sent = q.astype(jnp.float32) * scale
        else:
            reduced = jax.lax.psum(kept, axis_name) / n_pods
            sent = kept
        return reduced.astype(g.dtype), x - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
