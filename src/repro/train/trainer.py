"""Train-step builder: FSDP+TP sharded AdamW training with optional
microbatch accumulation and compressed cross-pod gradient reduction.

State layout:
  state = {"params": f32 master tree, "opt": {m, v, step},
           "err": error-feedback tree (only when compression is on)}

The forward pass casts matrix leaves to bf16 (MXU operand width); gradients
and optimizer math are f32. Parameters, m and v share one sharding tree
(ZeRO-3 over the 'data' axis + TP over 'model' — see
distributed/sharding.py), so optimizer state adds 8 bytes/param spread over
the whole mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    param_shardings, use_sharding,
)
from repro.models.model import init_params, loss_fn
from .compression import CompressionConfig, compressed_psum, \
    init_error_feedback
from .optimizer import AdamWConfig, adamw_init, adamw_update, warmup_cosine

__all__ = ["make_train_state", "make_train_step", "cast_for_compute",
           "train_state_shardings", "batch_sharding",
           "publish_train_metrics"]


def publish_train_metrics(metrics: dict, step: Optional[int] = None) -> None:
    """Stream a train-step metrics dict (loss / grad_norm / lr / ...)
    through the obs registry as ``repro_train_<name>`` gauges plus a
    ``repro_train_steps_total`` counter.

    No-op with REPRO_OBS off. When on, coercing the device scalars to
    float blocks on the step — call it at your logging cadence, not every
    step, if that matters (the scalars are tiny; the sync is the cost)."""
    from repro import obs
    if not obs.enabled():
        return
    for name, value in metrics.items():
        try:
            v = float(value)
        except (TypeError, ValueError):
            continue                    # non-scalar entry: skip, don't die
        obs.gauge(f"repro_train_{name}",
                  f"latest train-step metric {name!r}").set(v)
    obs.counter("repro_train_steps_total",
                "train steps streamed through the registry").inc()
    if step is not None:
        obs.gauge("repro_train_step", "latest published step index").set(
            float(step))


def cast_for_compute(params):
    """Master f32 -> compute dtypes: matrix leaves bf16, vectors f32."""
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.ndim >= 2 and
        jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def make_train_state(key, cfg, compression: Optional[CompressionConfig] = None):
    params = jax.tree.map(
        lambda p: p.astype(jnp.float32) if jnp.issubdtype(p.dtype, jnp.floating)
        else p, init_params(key, cfg))
    state = {"params": params, "opt": adamw_init(params)}
    if compression and compression.enabled:
        state["err"] = init_error_feedback(params)
    return state


def train_state_shardings(state, mesh, rules=None):
    """Sharding tree for the full train state (opt m/v mirror params)."""
    ps = param_shardings(state["params"], mesh, rules)
    out = {"params": ps, "opt": {
        "m": ps, "v": ps,
        "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }}
    if "err" in state:
        out["err"] = ps
    return out


def batch_sharding(mesh, rules=None):
    from repro.distributed.sharding import logical_to_spec
    with use_sharding(mesh, rules):
        spec = logical_to_spec(("batch", None))
    return jax.NamedSharding(mesh, spec)


def _grads_and_loss(params, cfg, batch, num_microbatches: int):
    """Loss + grads, with optional lax.scan microbatch accumulation."""
    def lf(p, b):
        return loss_fn(cast_for_compute(p), cfg, b)

    if num_microbatches <= 1:
        return jax.value_and_grad(lf)(params, batch)

    def split(x):
        b = x.shape[0]
        return x.reshape(num_microbatches, b // num_microbatches,
                         *x.shape[1:])

    mb = jax.tree.map(split, batch)

    def body(acc, b):
        loss, g = jax.value_and_grad(lf)(params, b)
        acc_loss, acc_g = acc
        return (acc_loss + loss,
                jax.tree.map(jnp.add, acc_g, g)), None

    zero = (jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss, grads), _ = jax.lax.scan(body, zero, mb)
    inv = 1.0 / num_microbatches
    return loss * inv, jax.tree.map(lambda g: g * inv, grads)


def make_train_step(cfg, opt_cfg: AdamWConfig,
                    compression: Optional[CompressionConfig] = None,
                    num_microbatches: int = 1,
                    mesh=None, rules=None):
    """Returns train_step(state, batch) -> (state, metrics).

    Plain path: pure jit + GSPMD (gradient reductions auto-inserted).
    Compressed path: shard_map over 'pod' (data/model stay auto-sharded);
    per-pod grads -> top-k/int8 compressed psum -> identical AdamW update on
    every pod."""
    schedule = warmup_cosine(opt_cfg)

    def plain_step(state, batch):
        loss, grads = _grads_and_loss(
            state["params"], cfg, batch, num_microbatches)
        new_p, new_opt, metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg, schedule)
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_opt, **(
            {"err": state["err"]} if "err" in state else {})}, metrics

    if not (compression and compression.enabled):
        return plain_step

    assert mesh is not None and "pod" in mesh.axis_names, \
        "compressed reduction needs the multi-pod mesh"
    n_pods = mesh.shape["pod"]
    P = jax.sharding.PartitionSpec

    def pod_body(state, batch):
        # inside: arrays are per-pod shards; data/model sharding stays auto
        loss, grads = _grads_and_loss(
            state["params"], cfg, batch, num_microbatches)
        grads, new_err = compressed_psum(
            grads, state["err"], compression, "pod", n_pods)
        loss = jax.lax.pmean(loss, "pod")
        new_p, new_opt, metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg, schedule)
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_opt, "err": new_err}, metrics

    def compressed_step(state, batch):
        specs_state = jax.tree.map(lambda _: P(), state)
        specs_batch = jax.tree.map(lambda _: P("pod"), batch)
        out_specs = (specs_state, {"loss": P(), "grad_norm": P(), "lr": P()})
        return jax.shard_map(
            pod_body, mesh=mesh,
            in_specs=(specs_state, specs_batch),
            out_specs=out_specs,
            check_vma=False,
            axis_names={"pod"},
        )(state, batch)

    return compressed_step
