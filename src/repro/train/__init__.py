# Training substrate: from-scratch AdamW, LR schedules, gradient clipping,
# gradient compression (top-k + int8, error feedback), train-step builder.
