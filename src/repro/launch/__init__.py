# Launchers: production mesh builder, multi-pod dry-run, train/serve drivers.
