"""Production mesh builder.

Defined as a FUNCTION so importing this module never touches jax device
state. Single pod: 16x16 = 256 chips ('data', 'model'); multi-pod:
2x16x16 = 512 chips ('pod', 'data', 'model') — the 'pod' axis is pure data
parallelism across pods (slow inter-pod links carry only gradient
reductions, optionally compressed; see train/compression.py).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires
    --xla_force_host_platform_device_count to cover the shape)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
