"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable_shapes

DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "dryrun")

IMPROVE_HINTS = {
    "memory": ("fuse attention probabilities into a Pallas flash kernel / "
               "raise arithmetic intensity (bigger per-chip batch)"),
    "collective": ("bf16 TP reductions + sequence-parallel norm regions; "
                   "EP-friendlier expert placement"),
    "compute": "remat policy tuning (save dots) to cut recompute",
}


def load(mesh: str) -> dict:
    out = {}
    for f in glob.glob(os.path.join(DIR, mesh, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(cells: dict) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful ratio | roofline frac | peak GiB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS[:-1]:
        cfg = get_config(arch)
        for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            if shape not in applicable_shapes(cfg):
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | — | — | — | — | "
                    f"skipped: full-attention arch at 500k ctx "
                    f"(DESIGN.md §Arch-applicability) |")
                continue
            r = cells.get((arch, shape))
            if r is None or not r.get("ok"):
                err = (r or {}).get("error", "missing")
                lines.append(f"| {arch} | {shape} | FAILED: {err[:60]} |"
                             + " — |" * 9)
                continue
            rt = r["roofline"]
            peak = r["memory"]["peak_per_device"] / 2 ** 30
            dom = rt["dominant"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rt['compute_s'])} | "
                f"{fmt_s(rt['memory_s'])} | {fmt_s(rt['collective_s'])} | "
                f"**{dom}** | {rt['model_flops']:.2e} | "
                f"{rt['useful_ratio']:.2f} | {rt['roofline_fraction']:.3f} | "
                f"{peak:.1f} | {IMPROVE_HINTS[dom][:58]} |")
    return "\n".join(lines)


def dryrun_table(cells256: dict, cells512: dict) -> str:
    lines = [
        "| arch | shape | pod256 | pod512 | peak256 GiB | peak512 GiB | "
        "coll bytes/dev 256 | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = 0
    for arch in ARCHS[:-1]:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            a = cells256.get((arch, shape))
            b = cells512.get((arch, shape))
            ok_a = "OK" if a and a.get("ok") else "FAIL"
            ok_b = "OK" if b and b.get("ok") else "FAIL"
            n_ok += int(ok_a == "OK") + int(ok_b == "OK")
            pa = a["memory"]["peak_per_device"] / 2 ** 30 if a and a.get("ok") else 0
            pb = b["memory"]["peak_per_device"] / 2 ** 30 if b and b.get("ok") else 0
            cb = (a["hlo_analysis"]["collective_bytes_per_device"] / 1e9
                  if a and a.get("ok") else 0)
            cs = a.get("compile_s", 0) if a else 0
            lines.append(f"| {arch} | {shape} | {ok_a} | {ok_b} | {pa:.1f} | "
                         f"{pb:.1f} | {cb:.1f} GB | {cs} |")
    lines.append(f"\n{n_ok} cell-compilations passed.")
    return "\n".join(lines)


def main():
    c256 = load("pod256")
    c512 = load("pod512")
    print("## §Dry-run (lower+compile, 16x16 and 2x16x16 meshes)\n")
    print(dryrun_table(c256, c512))
    print("\n## §Roofline (single-pod, 256 chips, TPU v5e constants)\n")
    print(roofline_table(c256))


if __name__ == "__main__":
    main()
