import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * 16x16 single-pod mesh (256 chips) and 2x16x16 multi-pod mesh (512),
  * every assigned architecture x its applicable input shapes,
  * train_4k lowers train_step (AdamW included), prefill_32k lowers the
    forward prefill, decode/long lower serve_step against a full cache,
  * serve cells run the paper-faithful M2XFP deployment (weights packed at
    4.5 bits/element, online Elem-EM activation quantization),
  * memory_analysis() proves fit; cost_analysis() + the loop-aware HLO
    analyzer (analysis/hlo.py) feed the roofline table.

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

os.environ.setdefault("REPRO_FAITHFUL_DOTS", "1")   # keep bf16 operand widths

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.core import envflags
from repro.analysis.roofline import model_flops, roofline
from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable_shapes, input_specs
from repro.distributed.sharding import (
    cache_shardings, param_shardings, logical_to_spec, use_sharding,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import (
    decode_step, forward, init_caches, init_params, pack_params_for_serving,
)
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import make_train_state, make_train_step, \
    train_state_shardings

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# Gradient-accumulation microbatches per arch for train_4k: bounds the live
# activation set (layer-boundary remat stubs + MoE dispatch transients) to
# fit 16 GB v5e HBM. global_batch stays 256; microbatch = 256 / N.
TRAIN_MICROBATCHES = {
    "qwen2-0.5b": 1, "xlstm-125m": 1,
    "mixtral-8x22b": 8, "zamba2-7b": 8,
}
DEFAULT_MICROBATCHES = 4


def _data_shardings(batch_specs: dict, mesh, rules=None):
    from jax.sharding import NamedSharding
    with use_sharding(mesh, rules):
        out = {}
        for k, v in batch_specs.items():
            axes = ("batch",) + (None,) * (len(v.shape) - 1)
            out[k] = NamedSharding(mesh, logical_to_spec(axes, v.shape))
        return out


def build_lowered(arch: str, shape_name: str, mesh, quant_train: str = "none",
                  rules=None):
    """Returns (lowered, meta) for one cell."""
    base_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape["kind"]
    key = jax.random.key(0)

    if kind == "train":
        cfg = dataclasses.replace(base_cfg, quant=quant_train)
        moe_group = envflags.get_int("REPRO_MOE_GROUP")
        if moe_group is not None:
            cfg = dataclasses.replace(cfg, moe_group_size=moe_group)
        state_sds = jax.eval_shape(
            lambda: make_train_state(key, cfg))
        batch_sds = input_specs(cfg, shape_name)
        mb = TRAIN_MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)
        with use_sharding(mesh, rules):
            state_sh = train_state_shardings(state_sds, mesh, rules)
            batch_sh = _data_shardings(batch_sds, mesh, rules)
            step = make_train_step(cfg, AdamWConfig(), num_microbatches=mb)
            fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=0)
            lowered = fn.lower(state_sds, batch_sds)
        return lowered, dict(cfg=cfg, shape=shape)

    # serving cells: packed M2XFP weights (the paper-faithful deployment);
    # REPRO_KV_QUANT=m2xfp additionally packs the KV cache (Sec. 6.4 lever)
    cfg = dataclasses.replace(
        base_cfg, quant="serve",
        kv_quant=envflags.get_str("REPRO_KV_QUANT"))
    params_sds = jax.eval_shape(lambda: init_params(key, cfg))
    packed_sds = jax.eval_shape(
        lambda p: pack_params_for_serving(p, cfg), params_sds)
    batch_sds = input_specs(cfg, shape_name)

    if kind == "prefill":
        with use_sharding(mesh, rules):
            p_sh = param_shardings(packed_sds, mesh, rules)
            b_sh = _data_shardings(batch_sds, mesh, rules)
            fn = jax.jit(lambda p, b: forward(p, cfg, b),
                         in_shardings=(p_sh, b_sh))
            lowered = fn.lower(packed_sds, batch_sds)
        return lowered, dict(cfg=cfg, shape=shape)

    # decode: one token against a pre-filled cache of seq_len
    cache_sds = jax.eval_shape(
        lambda: init_caches(cfg, shape["batch"], shape["seq"]))
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    with use_sharding(mesh, rules):
        p_sh = param_shardings(packed_sds, mesh, rules)
        b_sh = _data_shardings(batch_sds, mesh, rules)
        c_sh = cache_shardings(cache_sds, mesh, rules)
        from jax.sharding import NamedSharding, PartitionSpec
        i_sh = NamedSharding(mesh, PartitionSpec())
        fn = jax.jit(lambda p, b, c, i: decode_step(p, cfg, b, c, i),
                     in_shardings=(p_sh, b_sh, c_sh, i_sh),
                     donate_argnums=2)
        lowered = fn.lower(packed_sds, batch_sds, cache_sds, idx_sds)
    return lowered, dict(cfg=cfg, shape=shape)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant_train: str = "none", save: bool = True) -> dict:
    mesh_name = "pod512" if multi_pod else "pod256"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = None
    if shape_name == "long_500k":
        # batch=1: context-parallel over BOTH axes (500k cache / 256 shards)
        rules = {"kv_seq": ("data", "model")}
    # perf-iteration lever: logical-rule overrides, e.g.
    # REPRO_RULES_JSON='{"fsdp": null, "mlp": ["data","model"]}'
    env_rules = envflags.get_str("REPRO_RULES_JSON")
    if env_rules:
        overrides = {
            k: (tuple(v) if isinstance(v, list) else v)
            for k, v in json.loads(env_rules).items()}
        rules = {**(rules or {}), **overrides}
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "ok": False}
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh,
                                      quant_train, rules)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        text = compiled.as_text()
        hlo = analyze_hlo(text)
        mf = model_flops(meta["cfg"], meta["shape"])
        rt = roofline(hlo.flops, hlo.hbm_bytes, hlo.collective_bytes,
                      chips, mf)
        result.update({
            "ok": True,
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes,
                # caveat metric: the CPU backend hoists f32 mirrors of
                # bf16 loop buffers (no bf16 dot kernels on CPU); a TPU
                # MXU consumes bf16 directly, so the true peak is lower
                # by up to this amount (see hlo.py).
                "cpu_f32_mirror_bytes": hlo.f32_mirror_bytes,
            },
            "cost_analysis": {
                "flops_per_device_unrolled_once": ca.get("flops", 0.0),
                "bytes_accessed_once": ca.get("bytes accessed", 0.0),
            },
            "hlo_analysis": {
                "flops_per_device": hlo.flops,
                "hbm_bytes_per_device": hlo.hbm_bytes,
                "collective_bytes_per_device": hlo.collective_bytes,
                "per_kind_bytes": hlo.per_kind_bytes,
                "per_kind_count": hlo.per_kind_count,
                "loop_trips": hlo.loop_trips,
            },
            "roofline": rt.as_dict(),
        })
    except Exception as e:  # noqa: BLE001 — a cell failure is a data point
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    if save:
        d = os.path.join(RESULTS_DIR, mesh_name)
        os.makedirs(d, exist_ok=True)
        safe_arch = arch.replace(".", "_")
        with open(os.path.join(d, f"{safe_arch}__{shape_name}.json"),
                  "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant-train", default="none",
                    choices=["none", "qat"])
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS[:-1]) if args.all else [args.arch]  # paper cfg excluded
    for arch in archs:
        if arch is None:
            ap.error("--arch or --all required")
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg) if args.shape is None \
            else [args.shape]
        for sh in shapes:
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, sh, mp))

    for arch, sh, mp in cells:
        r = run_cell(arch, sh, mp, args.quant_train)
        status = "OK " if r["ok"] else "FAIL"
        extra = ""
        if r["ok"]:
            rt = r["roofline"]
            extra = (f"dom={rt['dominant']:10s} "
                     f"frac={rt['roofline_fraction']:.3f} "
                     f"peak/dev={r['memory']['peak_per_device']/2**30:.2f}GiB "
                     f"compile={r['compile_s']}s")
        else:
            extra = r["error"][:160]
        print(f"[{status}] {r['mesh']} {arch:16s} {sh:12s} {extra}",
              flush=True)


if __name__ == "__main__":
    main()
