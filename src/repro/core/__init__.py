# The paper's primary contribution: the M2XFP metadata-augmented
# microscaling format family, its baselines, and the encoding DSE.
# Everything referenced by docs/format.md is exported here.
from .dtypes import (  # noqa: F401
    FP4_E2M1, FP6_E2M3, FP8_E4M3, FP4_MAG_VALUES, FP6_MAG_VALUES,
    FloatSpec, exp2int, fp4_code_to_value, fp4_value_to_code,
    fp6_code_to_value, fp6_value_to_code, round_to_grid,
)
from .scaling import (  # noqa: F401
    SCALE_RULES, e8m0_decode, e8m0_encode, shared_scale_exponent,
)
from .packing import (  # noqa: F401
    group_reshape, group_unreshape, pack_meta2, pack_nibbles,
    unpack_meta2, unpack_nibbles,
)
from .formats import (  # noqa: F401
    quantize_fp4_fp16scale, quantize_mxfp4, quantize_nvfp4, quantize_smx4,
)
from .m2xfp import (  # noqa: F401
    PackedM2XFP,
    decode_act_m2xfp, decode_weight_m2xfp,
    elem_em_dequant_with_scale, sg_em_dequant_with_scale,
    encode_act_m2xfp, encode_weight_m2xfp,
    quantize_act_m2nvfp4, quantize_act_m2xfp,
    quantize_weight_m2nvfp4, quantize_weight_m2xfp,
)
from .dse import STRATEGIES, Strategy, mxfp4_reference, run_strategy  # noqa: F401
from .ebw import ebw, format_ebw  # noqa: F401

__all__ = [
    "FP4_E2M1", "FP4_MAG_VALUES", "FP6_E2M3", "FP6_MAG_VALUES", "FP8_E4M3",
    "FloatSpec", "PackedM2XFP", "SCALE_RULES", "STRATEGIES", "Strategy",
    "decode_act_m2xfp", "decode_weight_m2xfp", "e8m0_decode", "e8m0_encode",
    "ebw", "elem_em_dequant_with_scale", "encode_act_m2xfp",
    "encode_weight_m2xfp", "exp2int", "format_ebw", "fp4_code_to_value",
    "fp4_value_to_code", "fp6_code_to_value", "fp6_value_to_code",
    "group_reshape", "group_unreshape", "mxfp4_reference", "pack_meta2",
    "pack_nibbles", "quantize_act_m2nvfp4", "quantize_act_m2xfp",
    "quantize_fp4_fp16scale", "quantize_mxfp4", "quantize_nvfp4",
    "quantize_smx4", "quantize_weight_m2nvfp4", "quantize_weight_m2xfp",
    "round_to_grid", "run_strategy", "sg_em_dequant_with_scale",
    "shared_scale_exponent", "unpack_meta2", "unpack_nibbles",
]
