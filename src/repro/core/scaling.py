"""Shared-scale (E8M0) computation rules for MX-style quantization.

The shared scale of a group is S = 2^E derived from the block maximum ``amax``.
Five rules from the paper (Sec. 6.4, Tbl. 8):

  floor : E = floor(log2(amax / P))          (OCP default; P = largest PoT, 4 for FP4)
  ceil  : E = ceil (log2(amax / M))          (M = max representable, 6 for FP4)
  rtn1  : E = round(log2(amax / M))
  rtn2  : E = round(log2(amax / P))
  rtne  : rounds amax in value space then floors; for FP4 (M = 1.5 P) this is
          provably identical to ``ceil`` (paper Sec. 6.4), which is how we
          implement it.

E is clamped to the E8M0 range [-127, 127]. amax == 0 gives E = 0 (S = 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .dtypes import FP4_E2M1, FloatSpec, exp2int, floor_log2

__all__ = ["SCALE_RULES", "shared_scale_exponent", "e8m0_encode", "e8m0_decode"]

SCALE_RULES = ("floor", "ceil", "rtn1", "rtn2", "rtne")

# OCP E8M0 reaches 2^-127; we clamp to -126 so every scale is a
# *normal* f32 and scaling arithmetic stays exact (amax < 2^-120
# is numerically zero for LLM tensors)
_E8M0_MIN, _E8M0_MAX = -126, 127


def _ceil_log2(x: jax.Array) -> jax.Array:
    """Exact ceil(log2(x)) for x > 0."""
    fl = floor_log2(x)
    exact_pow2 = x == exp2int(fl)
    return jnp.where(exact_pow2, fl, fl + 1)


@partial(jax.jit, static_argnames=("rule", "spec"))
def shared_scale_exponent(
    amax: jax.Array, rule: str = "floor", spec: FloatSpec = FP4_E2M1
) -> jax.Array:
    """Integer exponent E of the shared scale S = 2^E for each group.

    ``amax``: per-group maximum absolute value (any shape). Returns int32 E of
    the same shape, clamped to the E8M0 range.
    """
    amax = amax.astype(jnp.float32)
    p = jnp.float32(spec.max_pow2)
    m = jnp.float32(spec.max_value)
    safe = jnp.maximum(amax, jnp.float32(1e-30))
    if rule == "floor":
        e = floor_log2(safe / p)
    elif rule in ("ceil", "rtne"):
        e = _ceil_log2(safe / m)
    elif rule == "rtn1":
        e = jnp.round(jnp.log2(safe / m)).astype(jnp.int32)
    elif rule == "rtn2":
        e = jnp.round(jnp.log2(safe / p)).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(f"unknown scale rule {rule!r}; one of {SCALE_RULES}")
    e = jnp.where(amax == 0, 0, e)
    return jnp.clip(e.astype(jnp.int32), _E8M0_MIN, _E8M0_MAX)


def e8m0_encode(e: jax.Array) -> jax.Array:
    """Exponent int -> biased u8 storage (bias 127; 255 reserved/NaN unused)."""
    return (jnp.clip(e, _E8M0_MIN, _E8M0_MAX) + 127).astype(jnp.uint8)


def e8m0_decode(b: jax.Array) -> jax.Array:
    """Biased u8 -> scale value 2^E as f32 (exact)."""
    return exp2int(b.astype(jnp.int32) - 127)
