"""Bit packing utilities for the M2XFP memory layout.

Paper Sec. 5.2: per group of 32 elements, three separately-organized streams:
  * 128-bit block of packed 4-bit element codes  -> u8[16]  (2 codes / byte)
  * 8-bit shared scale (E8M0, biased)            -> u8[1]
  * 8-bit metadata (4 subgroups x 2 bits)        -> u8[1]

Element code layout (sign-magnitude): bit3 = sign, bits2..0 = E2M1 magnitude
code. Low nibble = even index, high nibble = odd index.
Metadata byte layout: subgroup j occupies bits (2j)..(2j+1), j = 0..3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "group_reshape", "group_unreshape", "pack_nibbles", "unpack_nibbles",
    "pack_meta2", "unpack_meta2",
]


def group_reshape(x: jax.Array, group: int) -> jax.Array:
    """(..., n) -> (..., n // group, group). n must divide evenly."""
    n = x.shape[-1]
    if n % group:
        raise ValueError(f"last dim {n} not divisible by group {group}")
    return x.reshape(*x.shape[:-1], n // group, group)


def group_unreshape(x: jax.Array) -> jax.Array:
    """(..., n_groups, group) -> (..., n)."""
    return x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])


def pack_nibbles(codes: jax.Array) -> jax.Array:
    """int 4-bit codes (..., n) with n even -> u8 (..., n // 2)."""
    c = codes.astype(jnp.uint8) & 0xF
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """u8 (..., n // 2) -> int32 4-bit codes (..., n)."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def pack_meta2(meta: jax.Array) -> jax.Array:
    """2-bit fields (..., n_sub) with n_sub multiple of 4 -> u8 (..., n_sub // 4)."""
    m = meta.astype(jnp.uint8) & 0x3
    m4 = m.reshape(*m.shape[:-1], m.shape[-1] // 4, 4)
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    return jnp.sum(
        m4.astype(jnp.uint32) << shifts.astype(jnp.uint32), axis=-1
    ).astype(jnp.uint8)


def unpack_meta2(packed: jax.Array, n_sub: int) -> jax.Array:
    """u8 (..., n_sub // 4) -> int32 2-bit fields (..., n_sub)."""
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    fields = (packed[..., None] >> shifts) & 0x3
    return fields.reshape(*packed.shape[:-1], n_sub).astype(jnp.int32)
