"""Encoding design-space exploration (paper Sec. 4.1-4.2, Figs. 5-7).

Unified subgroup-centric framework: a group of ``k`` elements with shared
scale is divided into contiguous subgroups; metadata is spent either on the
most critical element (Elem-*) or on the subgroup scale (Sg-*), as extra
mantissa (EM, precision) or extra exponent (EE, range), under a *fixed*
shared scale (floor rule from the block max) or an *adaptive* one (MSE search
over exponent bias candidates E-1, E, E+1).

Each strategy yields (EBW, dequantized tensor); benchmarks sweep subgroup
sizes to trace the Pareto frontier of MSE vs EBW.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .dtypes import FP4_E2M1, FP6_E2M3, exp2int, round_to_grid
from .ebw import ebw
from .m2xfp import elem_em_dequant_with_scale, sg_em_dequant_with_scale
from .packing import group_reshape, group_unreshape
from .scaling import shared_scale_exponent

__all__ = ["Strategy", "STRATEGIES", "run_strategy", "mxfp4_reference"]


def _scales(xg: jax.Array, rule: str = "floor") -> jax.Array:
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    return exp2int(e)


def _subgroup(xg: jax.Array, subgroup: int) -> jax.Array:
    g = xg.shape[-1]
    return xg.reshape(*xg.shape[:-1], g // subgroup, subgroup)


# --------------------------------------------------------------------------
# Elem-EE: metadata as an exponent offset on the top-1 element
# --------------------------------------------------------------------------

def _elem_ee_dequant(xg, s, subgroup: int, bits: int = 2) -> jax.Array:
    """Top-1 element gets candidates fp4 * 2^d, d in {0..2^bits-1}; the best
    (by |error| vs the original) is kept. Range extension, no extra precision
    — the paper's analysis (Sec. 4.2) predicts this cannot fix block-max
    clipping error; included for DSE completeness."""
    xs = xg / s
    q4 = round_to_grid(xs, FP4_E2M1)
    q4s = _subgroup(q4, subgroup)
    xss = _subgroup(xs, subgroup)
    top_idx = jnp.argmax(jnp.abs(q4s), axis=-1)
    onehot = jax.nn.one_hot(top_idx, subgroup, dtype=xg.dtype)
    x_top = jnp.take_along_axis(xss, top_idx[..., None], axis=-1)[..., 0]
    best = jnp.take_along_axis(q4s, top_idx[..., None], axis=-1)[..., 0]
    best_err = jnp.abs(best - x_top)
    for d in range(1, 2 ** bits):
        cand = round_to_grid(x_top / (2.0 ** d), FP4_E2M1) * (2.0 ** d)
        err = jnp.abs(cand - x_top)
        take = err < best_err
        best = jnp.where(take, cand, best)
        best_err = jnp.where(take, err, best_err)
    bestb = jnp.broadcast_to(best[..., None], q4s.shape).reshape(q4.shape)
    dq = jnp.where(onehot.reshape(q4.shape) > 0, bestb, q4)
    return dq * s


# --------------------------------------------------------------------------
# Sg-EE: metadata as a subgroup exponent offset (SMX-style), fixed/adaptive
# --------------------------------------------------------------------------

def _sg_ee_dequant(xg, s, subgroup: int, bits: int = 1,
                   adaptive: bool = False) -> jax.Array:
    """Subgroup scale 2^(E - d), d in {0..2^bits-1}. Fixed mode derives d from
    the subgroup max (largest downshift that avoids clipping); adaptive mode
    MSE-searches d jointly with a group bias in {-1, 0, +1}."""
    nd = 2 ** bits
    xsub = _subgroup(xg, subgroup)

    def best_for_scale(base_s):
        best_err = jnp.full(xsub.shape[:-1], jnp.inf, dtype=jnp.float32)
        best_dq = jnp.zeros_like(xsub)
        for d in range(nd):
            sd = base_s[..., None] * (2.0 ** -d)
            dq = round_to_grid(xsub / sd, FP4_E2M1) * sd
            err = jnp.sum((dq - xsub) ** 2, axis=-1)
            take = err < best_err
            best_err = jnp.where(take, err, best_err)
            best_dq = jnp.where(take[..., None], dq, best_dq)
        return best_err, best_dq

    if not adaptive:
        # fixed: pick d from the subgroup max (no search over the global E)
        smax = jnp.max(jnp.abs(xsub), axis=-1, keepdims=True)
        fits = [smax * (2.0 ** d) <= FP4_E2M1.max_value * s[..., None]
                for d in range(nd)]
        d_sel = jnp.zeros(smax.shape, jnp.float32)
        for d in range(nd - 1, 0, -1):
            d_sel = jnp.where(fits[d], float(d), d_sel)
        sd = s[..., None] * exp2int(-d_sel.astype(jnp.int32))
        dq = round_to_grid(xsub / sd, FP4_E2M1) * sd
        return dq.reshape(xg.shape)

    best_err = None
    best_dq = None
    for b in (-1, 0, 1):
        err, dq = best_for_scale(s * (2.0 ** b))
        gerr = jnp.sum(err, axis=-1, keepdims=True)
        if best_err is None:
            best_err, best_dq = gerr, dq
        else:
            take = gerr < best_err
            best_err = jnp.where(take, gerr, best_err)
            best_dq = jnp.where(take[..., None], dq, best_dq)
    return best_dq.reshape(xg.shape)


# --------------------------------------------------------------------------
# Strategy registry
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Strategy:
    """One point family in the metadata design space."""

    name: str
    meta_bits_per_subgroup: float
    fn: Callable  # (xg, s, subgroup) -> dequantized (..., ng, group)

    def ebw(self, group: int, subgroup: int) -> float:
        return ebw(group, meta_bits=self.meta_bits_per_subgroup * (group // subgroup))


def _adaptive_scale_wrap(base_fn, xg, s, subgroup):
    """Adaptive shared scale for element-level strategies: MSE-search the
    group exponent over {E-1, E, E+1} (metadata unchanged)."""
    best_err, best_dq = None, None
    for b in (-1, 0, 1):
        dq = base_fn(xg, s * (2.0 ** b), subgroup)
        err = jnp.sum((dq - xg) ** 2, axis=-1, keepdims=True)
        if best_err is None:
            best_err, best_dq = err, dq
        else:
            take = err < best_err
            best_err = jnp.where(take, err, best_err)
            best_dq = jnp.where(take, dq, best_dq)
    return best_dq


STRATEGIES: dict[str, Strategy] = {
    # --- fixed shared scale (Fig. 6) ---
    "elem_em_top1": Strategy(
        "elem_em_top1", 2.0,
        lambda xg, s, sg: elem_em_dequant_with_scale(xg, s, sg, n_top=1)),
    "elem_em_top2": Strategy(
        "elem_em_top2", 4.0,
        lambda xg, s, sg: elem_em_dequant_with_scale(xg, s, sg, n_top=2)),
    "elem_ee": Strategy(
        "elem_ee", 2.0, lambda xg, s, sg: _elem_ee_dequant(xg, s, sg, bits=2)),
    "sg_em_1bit": Strategy(
        "sg_em_1bit", 1.0,
        lambda xg, s, sg: sg_em_dequant_with_scale(xg, s, sg, bits=1, adaptive=False)),
    "sg_em_2bit": Strategy(
        "sg_em_2bit", 2.0,
        lambda xg, s, sg: sg_em_dequant_with_scale(xg, s, sg, bits=2, adaptive=False)),
    "sg_ee_1bit": Strategy(
        "sg_ee_1bit", 1.0,
        lambda xg, s, sg: _sg_ee_dequant(xg, s, sg, bits=1, adaptive=False)),
    "sg_ee_2bit": Strategy(
        "sg_ee_2bit", 2.0,
        lambda xg, s, sg: _sg_ee_dequant(xg, s, sg, bits=2, adaptive=False)),
    # --- adaptive shared scale (Fig. 7) ---
    "elem_em_top1_adaptive": Strategy(
        "elem_em_top1_adaptive", 2.0,
        lambda xg, s, sg: _adaptive_scale_wrap(
            lambda a, b, c: elem_em_dequant_with_scale(a, b, c, n_top=1),
            xg, s, sg)),
    "sg_em_2bit_adaptive": Strategy(
        "sg_em_2bit_adaptive", 2.0,
        lambda xg, s, sg: sg_em_dequant_with_scale(xg, s, sg, bits=2, adaptive=True)),
    "sg_ee_2bit_adaptive": Strategy(
        "sg_ee_2bit_adaptive", 2.0,
        lambda xg, s, sg: _sg_ee_dequant(xg, s, sg, bits=2, adaptive=True)),
}


def run_strategy(name: str, x: jax.Array, group: int = 32,
                 subgroup: int = 8, rule: str = "floor"):
    """Apply a DSE strategy. Returns (dequantized, ebw)."""
    strat = STRATEGIES[name]
    xg = group_reshape(x.astype(jnp.float32), group)
    s = _scales(xg, rule)
    dq = strat.fn(xg, s, subgroup)
    return group_unreshape(dq).astype(x.dtype), strat.ebw(group, subgroup)


def mxfp4_reference(x: jax.Array, group: int = 32, rule: str = "floor"):
    """Plain MXFP4 as the zero-metadata reference point (EBW 4.25)."""
    xg = group_reshape(x.astype(jnp.float32), group)
    s = _scales(xg, rule)
    dq = round_to_grid(xg / s, FP4_E2M1) * s
    return group_unreshape(dq).astype(x.dtype), ebw(group)
