"""Typed registry for every ``REPRO_*`` environment flag.

The repo's behavior knobs used to be ad-hoc ``os.environ.get`` calls
scattered across models/, obs/, launch/ and the benches, each with its own
parsing and error handling. This module is the single source of truth: a
flag is *declared* once (name, type, default, docstring, optional choices/
minimum) and *read* through the typed accessors — which re-read the
environment on every call, so tests can monkeypatch ``os.environ`` freely
and nothing is cached behind their back.

The ``env-hygiene`` lint rule (``repro.analysis.lint``) enforces that no
module outside this one reads a ``REPRO_*`` variable directly; *writing*
flags (``os.environ.setdefault`` in launchers and benches, monkeypatching
in tests) is deliberately left alone.

``scripts/lint.py --list-env`` renders :func:`markdown_table` — the flag
table embedded in docs/static-analysis.md.

Parsing semantics (kept bit-compatible with the call sites this replaced):

* ``bool``  — true iff the raw value is exactly ``"1"``; unset, empty or
  anything else is false (the historical ``== "1"`` convention).
* ``int``   — unset returns the default (which may be ``None`` for
  optional flags); a non-integer or a value below ``minimum`` raises
  ``ValueError`` with an actionable message (see :func:`env_int`).
* ``str``   — unset returns the default; when ``choices`` is declared, any
  other raw value (including ``""``) raises ``ValueError`` listing them.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

__all__ = [
    "EnvFlag", "declare", "defined_flags", "get", "get_bool", "get_int",
    "get_str", "get_raw", "env_int", "markdown_table",
]

_KINDS = ("bool", "int", "str")


@dataclasses.dataclass(frozen=True)
class EnvFlag:
    """One declared environment flag (see module docstring for parsing)."""

    name: str
    kind: str                                # "bool" | "int" | "str"
    default: Any
    help: str
    choices: Optional[Tuple[str, ...]] = None   # str flags only
    minimum: Optional[int] = None               # int flags only


_FLAGS: Dict[str, EnvFlag] = {}


def declare(name: str, kind: str, default: Any, help: str, *,
            choices: Optional[Tuple[str, ...]] = None,
            minimum: Optional[int] = None) -> EnvFlag:
    """Register a flag. Redeclaring with an identical spec is a no-op (so
    modules may defensively re-declare); a conflicting spec is an error."""
    if kind not in _KINDS:
        raise ValueError(f"flag {name!r}: kind must be one of {_KINDS}, "
                         f"got {kind!r}")
    flag = EnvFlag(name, kind, default, help, choices=choices,
                   minimum=minimum)
    prev = _FLAGS.get(name)
    if prev is not None and prev != flag:
        raise ValueError(f"flag {name!r} already declared with a different "
                         f"spec: {prev} vs {flag}")
    _FLAGS[name] = flag
    return flag


def defined_flags() -> Tuple[EnvFlag, ...]:
    """Every declared flag, sorted by name."""
    return tuple(_FLAGS[n] for n in sorted(_FLAGS))


def _flag(name: str) -> EnvFlag:
    try:
        return _FLAGS[name]
    except KeyError:
        raise KeyError(
            f"environment flag {name!r} is not declared in "
            f"repro.core.envflags; declared flags: "
            f"{', '.join(sorted(_FLAGS)) or '(none)'}") from None


def env_int(name: str, default: Optional[int],
            minimum: Optional[int] = 1) -> Optional[int]:
    """Ad-hoc integer env read with hard validation (usable for variables
    that are not declared flags — e.g. one-off test knobs). A non-integer
    or below-minimum value is a hard error: a zero or negative chunk/tile
    would silently produce broken tiling (division by zero, empty scans)
    far from the setting."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(
            f"{name}={raw!r}: not an integer (unset it for the default "
            f"{default})") from None
    if minimum is not None and v < minimum:
        raise ValueError(
            f"{name}={raw!r}: must be >= {minimum}; unset it for the "
            f"default {default}")
    return v


def get(name: str) -> Any:
    """Typed read of a declared flag (re-reads the environment each call)."""
    flag = _flag(name)
    if flag.kind == "bool":
        return os.environ.get(name, "") == "1"
    if flag.kind == "int":
        return env_int(name, flag.default, flag.minimum)
    raw = os.environ.get(name)
    if raw is None:
        return flag.default
    if flag.choices is not None and raw not in flag.choices:
        raise ValueError(
            f"{name}={raw!r}: expected one of "
            f"{', '.join(repr(c) for c in flag.choices)}")
    return raw


def get_raw(name: str) -> Optional[str]:
    """Unparsed read of a declared flag (None when unset) — for call sites
    with a bespoke grammar (e.g. the REPRO_OBS pillar list)."""
    _flag(name)
    return os.environ.get(name)


def _kind_checked(name: str, kind: str) -> Any:
    flag = _flag(name)
    if flag.kind != kind:
        raise TypeError(f"flag {name!r} is declared {flag.kind!r}, "
                        f"not {kind!r}")
    return get(name)


def get_bool(name: str) -> bool:
    return _kind_checked(name, "bool")


def get_int(name: str) -> Optional[int]:
    return _kind_checked(name, "int")


def get_str(name: str) -> Optional[str]:
    return _kind_checked(name, "str")


def markdown_table() -> str:
    """The declared flag surface as a markdown table (rendered by
    ``scripts/lint.py --list-env`` and embedded in docs)."""
    rows = ["| Flag | Type | Default | Description |",
            "| --- | --- | --- | --- |"]
    for f in defined_flags():
        extra = ""
        if f.choices:
            extra = " One of: " + ", ".join(f"`{c}`" for c in f.choices) + "."
        if f.minimum is not None and f.kind == "int":
            extra += f" Minimum {f.minimum}."
        default = "(unset)" if f.default in (None, "") else f"`{f.default}`"
        help_text = " ".join(f.help.split())
        rows.append(f"| `{f.name}` | {f.kind} | {default} | "
                    f"{help_text}{extra} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# The repo's flag surface (one declaration per REPRO_* variable)
# ---------------------------------------------------------------------------

declare("REPRO_FAITHFUL_DOTS", "bool", False,
        "Keep true bf16 GEMM operand widths in lowered HLO (what the TPU "
        "MXU consumes and the roofline memory term assumes). Off by "
        "default because the container's XLA CPU runtime cannot execute "
        "bf16xbf16=f32 dot thunks; the dry-run sets it (it only "
        "lowers+compiles).")
declare("REPRO_BF16_TP_REDUCE", "bool", False,
        "Emit bf16 dot outputs so GSPMD tensor-parallel partial-sum "
        "all-reduces move half the bytes (standard production trade: bf16 "
        "reduction of activations).")
declare("REPRO_GATHER_PACKED", "bool", False,
        "Constrain packed u8 weight streams to be replicated along the "
        "weight-shard axis before decode, so GSPMD all-gathers packed "
        "codes instead of 16-bit decoded weights (3.55x less wire "
        "traffic for the serve path's FSDP gathers).")
declare("REPRO_SERVE_KERNEL", "str", "auto",
        "Serve-path GEMM dispatch: 'xla' forces the pure-XLA decode "
        "mirror, 'pallas' prefers the codec's fused kernel (interpret "
        "mode off-TPU), 'auto' picks Pallas on TPU and XLA elsewhere "
        "(docs/kernels.md).",
        choices=("auto", "pallas", "xla"))
declare("REPRO_REMAT_POLICY", "str", "none",
        "jax.checkpoint policy for remat'd transformer blocks: 'none' "
        "saves only block inputs, 'dots' saves dot outputs, "
        "'dots_no_batch' saves dots with no batch dims.",
        choices=("none", "dots", "dots_no_batch"))
declare("REPRO_ATTN_KV_CHUNK", "int", 512,
        "KV-chunk length of the flash-style lax.scan in train/prefill "
        "attention. Larger: fewer scan iterations, less carry re-traffic; "
        "smaller: lower live memory.", minimum=1)
declare("REPRO_ATTN_Q_TILE", "int", 1024,
        "Query-tile length of train/prefill attention (pairs with "
        "REPRO_ATTN_KV_CHUNK).", minimum=1)
declare("REPRO_KV_QUANT", "str", "none",
        "KV-cache codec for the dry-run's serve cells: 'none' or a "
        "kv-capable codec name from repro.core.codecs (e.g. 'm2xfp').")
declare("REPRO_MOE_GROUP", "int", None,
        "Override moe_group_size for dry-run train cells (expert-group "
        "size of the MoE dispatch).", minimum=1)
declare("REPRO_RULES_JSON", "str", None,
        "JSON object of logical-sharding rule overrides for the dry-run, "
        "e.g. '{\"fsdp\": null, \"mlp\": [\"data\",\"model\"]}'.")
declare("REPRO_OBS", "str", "",
        "Observability master switch: unset/''/'0' all off, '1' every "
        "pillar, or a comma list of pillars (metrics, trace, health) — "
        "parsed by repro.obs.registry.")
declare("REPRO_OBS_DIR", "str", "",
        "When set, components that finish a unit of work drop "
        "metrics.jsonl + trace.json snapshots there (repro.obs.autodump).")
