"""M2XFP: the paper's hybrid metadata-augmented microscaling format.

Two encoders (paper Sec. 4.3-4.4):

  * Activations — **Elem-EM-top1** (Alg. 1, online): group 32 shares an E8M0
    scale; all elements quantize to FP4 E2M1; within each subgroup of 8 the
    top-1 element *by FP4 magnitude* (ties -> lowest index, so the decoder can
    re-identify it from the FP4 data alone) is re-quantized to FP6 E2M3 and its
    2 extra mantissa bits are stored via the bias-clamp encoding:

        stored = clamp(fp6_code + 1, fp4_code<<2, fp4_code<<2 | 3)
        meta   = stored & 0b11
        decode = fp6_from_code((fp4_code << 2 | meta) - 1)

    giving candidates {-1, 0, +1, +2} FP6 grid steps around the FP4 value
    (the -2 candidate is sacrificed for 2-bit alignment; paper shows the
    impact is negligible — validated in benchmarks).

  * Weights — **Sg-EM-2bit with adaptive shared scale** (Eq. 3-4, offline):
    each subgroup of 8 stores 2 bits selecting a scale multiplier
    (1 + k/4) * 2^E, k in {0..3}; a group exponent bias b in {-1, 0, +1} is
    chosen by hierarchical MSE search and absorbed into the stored scale.

Both produce 8 bits of metadata per group of 32 -> EBW = 4.5 bits.

The ``*_with_scale`` cores take an arbitrary positive per-group scale so the
same machinery builds M2-NVFP4 (paper Tbl. 6).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .dtypes import (
    FP4_E2M1, FP6_E2M3, FP8_E4M3, exp2int,
    fp4_code_to_value, fp4_value_to_code, fp6_code_to_value, fp6_value_to_code,
    round_to_grid,
)
from .packing import (
    group_reshape, group_unreshape, pack_meta2, pack_nibbles,
    unpack_meta2, unpack_nibbles,
)
from .scaling import e8m0_decode, e8m0_encode, shared_scale_exponent

__all__ = [
    "elem_em_dequant_with_scale", "sg_em_dequant_with_scale",
    "quantize_act_m2xfp", "quantize_weight_m2xfp",
    "quantize_act_m2nvfp4", "quantize_weight_m2nvfp4",
    "encode_act_m2xfp", "decode_act_m2xfp",
    "encode_weight_m2xfp", "decode_weight_m2xfp",
    "PackedM2XFP",
]


# --------------------------------------------------------------------------
# Elem-EM core (activations)
# --------------------------------------------------------------------------

def _subgroup(xg: jax.Array, subgroup: int) -> jax.Array:
    """(..., ng, group) -> (..., ng, n_sub, subgroup)."""
    g = xg.shape[-1]
    return xg.reshape(*xg.shape[:-1], g // subgroup, subgroup)


def elem_em_encode_parts(xg: jax.Array, s: jax.Array, subgroup: int):
    """Shared Elem-EM-top1 math. ``xg``: (..., ng, group) f32 originals;
    ``s``: (..., ng, 1) positive scales. Returns
    (q4 values (..., ng, group), top1 one-hot mask (..., ng, group),
     fp6_refined values at top1 (broadcast over subgroup), meta codes
     (..., ng, n_sub) int32, fp4 top codes (..., ng, n_sub))."""
    group = xg.shape[-1]
    n_sub = group // subgroup
    xs = xg / s
    q4 = round_to_grid(xs, FP4_E2M1)                       # FP4 grid values
    q4s = _subgroup(q4, subgroup)
    xss = _subgroup(xs, subgroup)

    c4 = fp4_value_to_code(jnp.abs(q4s))                   # 3-bit codes
    # Step 3-4: top-1 by FP4 magnitude, lowest index on ties. Written as
    # max + first-match cumsum + masked reduce (no argmax/gather/one_hot):
    # every op is elementwise or a small-axis reduction, so XLA fuses the
    # whole online encoder into a few passes (vital for the serve path).
    c4_top = jnp.max(c4, axis=-1)                          # (..., ng, n_sub)
    is_max = c4 == c4_top[..., None]
    top1 = is_max & (jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1)
    x_orig = jnp.sum(jnp.where(top1, xss, 0.0), axis=-1)

    # Step 5: requantize the original (scaled) value to FP6 E2M3.
    q6 = round_to_grid(x_orig, FP6_E2M3)
    c6 = fp6_value_to_code(jnp.abs(q6))                    # 5-bit codes

    # Step 6-7: bias-clamp encoding.
    encoded = c6 + 1
    rmin = c4_top << 2
    rmax = rmin | 3
    clamped = jnp.clip(encoded, rmin, rmax)
    meta = clamped & 3                                     # 2-bit metadata

    # Decode side (what the PE reconstructs).
    c6_dec = jnp.maximum((c4_top << 2) | meta, 1) - 1
    v6 = fp6_code_to_value(c6_dec) * jnp.sign(x_orig)
    return q4, top1.reshape(q4.shape), v6, meta, c4_top


def elem_em_dequant_with_scale(
    xg: jax.Array, s: jax.Array, subgroup: int, n_top: int = 1,
    encoding: str = "clamped",
) -> jax.Array:
    """Fake-quant Elem-EM: returns dequantized (..., ng, group) f32.

    ``n_top``: number of refined elements per subgroup (paper evaluates top-1
    and top-2; M2XFP uses top-1). ``encoding='ideal'`` replaces the top-1
    with its *unconstrained* FP6 value (no bias-clamp; unencodable in 2
    bits) — the paper's 'without rounding error' ablation comparator."""
    if n_top == 1:
        q4, top1, v6, _, _ = elem_em_encode_parts(xg, s, subgroup)
        if encoding == "ideal":
            q6 = round_to_grid(xg / s, FP6_E2M3)
            dq = jnp.where(top1, q6, q4)
            return dq * s
        v6b = jnp.broadcast_to(
            v6[..., None], (*v6.shape, subgroup)).reshape(q4.shape)
        dq = jnp.where(top1, v6b, q4)
        return dq * s
    # top-k (k>=2): refine the k largest by FP4 magnitude, lowest-index ties.
    group = xg.shape[-1]
    xs = xg / s
    q4 = round_to_grid(xs, FP4_E2M1)
    q4s = _subgroup(q4, subgroup)
    xss = _subgroup(xs, subgroup)
    c4 = fp4_value_to_code(jnp.abs(q4s))
    # stable ordering: scale codes so lower index wins ties
    order_key = c4 * subgroup + (subgroup - 1 - jnp.arange(subgroup))
    q6 = round_to_grid(xss, FP6_E2M3)
    c6 = fp6_value_to_code(jnp.abs(q6))
    c6_dec = jnp.maximum(jnp.clip(c6 + 1, c4 << 2, (c4 << 2) | 3), 1) - 1
    v6 = fp6_code_to_value(c6_dec) * jnp.sign(xss)
    thresh = jnp.sort(order_key, axis=-1)[..., subgroup - n_top, None]
    refined = order_key >= thresh
    dq = jnp.where(refined, v6, q4s).reshape(q4.shape)
    return dq * s


# --------------------------------------------------------------------------
# Sg-EM core (weights)
# --------------------------------------------------------------------------

def sg_em_dequant_with_scale(
    xg: jax.Array,
    s: jax.Array,
    subgroup: int,
    bits: int = 2,
    adaptive: bool = True,
    return_codes: bool = False,
):
    """Fake-quant Sg-EM: subgroup scale refinement (1 + k / 2^bits) * s with
    optional adaptive group exponent bias b in {-1, 0, +1} (Eq. 3-4).

    Hierarchical MSE search: best k per subgroup given b, then best b per
    group. Returns dequantized (..., ng, group); with ``return_codes`` also
    (k codes (..., ng, n_sub) int32, b (..., ng, 1) int32).
    """
    nk = 2 ** bits
    xsub = _subgroup(xg, subgroup)                          # (..., ng, ns, sg)

    def eval_bias(b):
        """Best per-subgroup k and its error for a given exponent bias."""
        best_err = jnp.full(xsub.shape[:-1], jnp.inf, dtype=jnp.float32)
        best_k = jnp.zeros(xsub.shape[:-1], dtype=jnp.int32)
        for k in range(nk):
            sk = (1.0 + k / nk) * s * (2.0 ** b)            # (..., ng, 1)
            skb = sk[..., None]                              # bcast subgroup
            dq = round_to_grid(xsub / skb, FP4_E2M1) * skb
            err = jnp.sum((dq - xsub) ** 2, axis=-1)
            take = err < best_err
            best_err = jnp.where(take, err, best_err)
            best_k = jnp.where(take, k, best_k)
        return best_err, best_k

    biases = (-1, 0, 1) if adaptive else (0,)
    errs, ks = [], []
    for b in biases:
        e, k = eval_bias(b)
        errs.append(jnp.sum(e, axis=-1))                    # (..., ng)
        ks.append(k)
    errs = jnp.stack(errs, axis=-1)
    b_idx = jnp.argmin(errs, axis=-1)                       # (..., ng)
    b_val = jnp.asarray(biases, dtype=jnp.int32)[b_idx]     # (..., ng)
    k_all = jnp.stack(ks, axis=-1)                          # (..., ng, ns, nb)
    k_sel = jnp.take_along_axis(
        k_all, b_idx[..., None, None], axis=-1
    )[..., 0]                                               # (..., ng, ns)

    s_final = (
        (1.0 + k_sel.astype(jnp.float32) / nk)
        * s
        * exp2int(b_val)[..., None]
    )[..., None]                                            # (..., ng, ns, 1)
    dq = round_to_grid(xsub / s_final, FP4_E2M1) * s_final
    dq = dq.reshape(xg.shape)
    if return_codes:
        return dq, k_sel, b_val
    return dq


# --------------------------------------------------------------------------
# Public fake-quant entry points (E8M0 shared scale -> "M2XFP")
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("group", "subgroup", "rule", "n_top",
                                   "encoding"))
def quantize_act_m2xfp(
    x: jax.Array, group: int = 32, subgroup: int = 8,
    rule: str = "floor", n_top: int = 1, encoding: str = "clamped",
) -> jax.Array:
    """Activation fake-quant: Elem-EM-top1 over E8M0 shared scale."""
    xg = group_reshape(x.astype(jnp.float32), group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    dq = elem_em_dequant_with_scale(xg, s, subgroup, n_top, encoding)
    return group_unreshape(dq).astype(x.dtype)


@partial(jax.jit, static_argnames=("group", "subgroup", "rule", "adaptive", "bits"))
def quantize_weight_m2xfp(
    w: jax.Array, group: int = 32, subgroup: int = 8,
    rule: str = "floor", adaptive: bool = True, bits: int = 2,
) -> jax.Array:
    """Weight fake-quant: Sg-EM-2bit + adaptive shared scale over E8M0."""
    wg = group_reshape(w.astype(jnp.float32), group)
    amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    dq = sg_em_dequant_with_scale(wg, s, subgroup, bits=bits, adaptive=adaptive)
    return group_unreshape(dq).astype(w.dtype)


# --------------------------------------------------------------------------
# M2-NVFP4 (paper Tbl. 6): same metadata machinery over NVFP4 scales
# --------------------------------------------------------------------------

def _nvfp4_scales(x: jax.Array, group: int):
    xg = group_reshape(x.astype(jnp.float32), group)
    amax_t = jnp.max(jnp.abs(x.astype(jnp.float32)))
    t = amax_t / (FP8_E4M3.max_value * FP4_E2M1.max_value)
    t = jnp.where(t == 0, 1.0, t)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s8 = round_to_grid(amax / (FP4_E2M1.max_value * t), FP8_E4M3)
    s = s8 * t
    return xg, jnp.where(s == 0, 1.0, s)


@partial(jax.jit, static_argnames=("group", "subgroup"))
def quantize_act_m2nvfp4(x: jax.Array, group: int = 16, subgroup: int = 4) -> jax.Array:
    xg, s = _nvfp4_scales(x, group)
    dq = elem_em_dequant_with_scale(xg, s, subgroup)
    return group_unreshape(dq).astype(x.dtype)


@partial(jax.jit, static_argnames=("group", "subgroup", "adaptive"))
def quantize_weight_m2nvfp4(
    w: jax.Array, group: int = 16, subgroup: int = 4, adaptive: bool = True
) -> jax.Array:
    wg, s = _nvfp4_scales(w, group)
    dq = sg_em_dequant_with_scale(wg, s, subgroup, bits=2, adaptive=adaptive)
    return group_unreshape(dq).astype(w.dtype)


# --------------------------------------------------------------------------
# Packed (real) representation — the serving memory layout of Sec. 5.2
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedM2XFP:
    """Packed M2XFP tensor: three contiguous streams per group of 32.

    codes: u8 (..., n/2)    — sign-magnitude FP4 codes, 2 per byte
    scale: u8 (..., n/32)   — biased E8M0 exponent per group
    meta:  u8 (..., n/32)   — 4 subgroups x 2 bits per group
    kind:  'act' (Elem-EM) | 'weight' (Sg-EM)
    """

    codes: jax.Array
    scale: jax.Array
    meta: jax.Array
    kind: str
    group: int
    subgroup: int
    orig_shape: tuple

    def tree_flatten(self):
        return (self.codes, self.scale, self.meta), (
            self.kind, self.group, self.subgroup, self.orig_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes_per_elem(self) -> float:
        n = 1
        for d in self.orig_shape:
            n *= d
        total = self.codes.size + self.scale.size + self.meta.size
        return total / n


def _sign_mag_code(values: jax.Array, signs: jax.Array) -> jax.Array:
    """FP4 grid values + sign -> 4-bit sign-magnitude codes (bit3 = sign)."""
    mag = fp4_value_to_code(jnp.abs(values))
    return jnp.where(signs < 0, mag | 8, mag).astype(jnp.int32)


def _sign_mag_decode(codes: jax.Array):
    mag = fp4_code_to_value(codes & 7)
    sign = jnp.where(codes & 8, -1.0, 1.0)
    return mag, sign


def encode_act_m2xfp(
    x: jax.Array, group: int = 32, subgroup: int = 8, rule: str = "floor"
) -> PackedM2XFP:
    """Pack activations to the M2XFP serving layout (Alg. 1 + Sec. 5.2)."""
    xg = group_reshape(x.astype(jnp.float32), group)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    q4, onehot, _, meta, _ = elem_em_encode_parts(xg, s, subgroup)
    from repro.obs.quant_health import probe_scaled
    probe_scaled("encode_act", xg / s, e, meta)     # REPRO_OBS health pillar
    # sign of the original value (keeps sign of values that round to FP4 zero,
    # matching the sign-magnitude hardware encoding)
    codes = _sign_mag_code(q4, jnp.where(xg < 0, -1.0, 1.0))
    packed_codes = pack_nibbles(codes.reshape(*x.shape[:-1], -1))
    packed_meta = pack_meta2(meta.reshape(*x.shape[:-1], -1))
    return PackedM2XFP(
        codes=packed_codes,
        scale=e8m0_encode(e[..., 0]).reshape(*x.shape[:-1], -1),
        meta=packed_meta,
        kind="act", group=group, subgroup=subgroup, orig_shape=tuple(x.shape),
    )


def decode_act_m2xfp(p: PackedM2XFP) -> jax.Array:
    """Dequantize a packed Elem-EM tensor (the Top-1 Decode Unit + PE math)."""
    group, subgroup = p.group, p.subgroup
    n = p.orig_shape[-1]
    codes = unpack_nibbles(p.codes).reshape(*p.orig_shape[:-1], n // group, group)
    mag, sign = _sign_mag_decode(codes)
    s = e8m0_decode(p.scale).reshape(*p.orig_shape[:-1], n // group, 1)
    n_sub = group // subgroup
    meta = unpack_meta2(p.meta.reshape(*p.orig_shape[:-1], -1), (n // group) * n_sub)
    meta = meta.reshape(*p.orig_shape[:-1], n // group, n_sub)

    mag_s = mag.reshape(*mag.shape[:-1], n_sub, subgroup)
    sign_s = sign.reshape(mag_s.shape)
    c4 = fp4_value_to_code(mag_s)
    top_idx = jnp.argmax(c4, axis=-1)                        # decode unit
    onehot = jax.nn.one_hot(top_idx, subgroup, dtype=jnp.float32)
    c4_top = jnp.take_along_axis(c4, top_idx[..., None], axis=-1)[..., 0]
    c6_dec = jnp.maximum((c4_top << 2) | meta, 1) - 1
    v6 = fp6_code_to_value(c6_dec)
    vals = jnp.where(onehot > 0, v6[..., None], mag_s) * sign_s
    dq = vals.reshape(*p.orig_shape[:-1], n // group, group) * s
    return group_unreshape(dq)


def encode_weight_m2xfp(
    w: jax.Array, group: int = 32, subgroup: int = 8,
    rule: str = "floor", adaptive: bool = True,
) -> PackedM2XFP:
    """Pack weights to the Sg-EM serving layout (scale absorbs the adaptive
    exponent bias b; metadata stores the 2-bit multiplier code k)."""
    wg = group_reshape(w.astype(jnp.float32), group)
    amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, rule)
    s = exp2int(e)
    _, k_sel, b_val = sg_em_dequant_with_scale(
        wg, s, subgroup, bits=2, adaptive=adaptive, return_codes=True)
    e_stored = e[..., 0] + b_val                              # absorb bias
    s_final = (1.0 + k_sel.astype(jnp.float32) / 4.0) * \
        exp2int(e_stored)[..., None]
    wsub = wg.reshape(*wg.shape[:-1], group // subgroup, subgroup)
    from repro.obs.quant_health import probe_scaled
    probe_scaled("encode_weight", wsub / s_final[..., None], e_stored, k_sel)
    q = round_to_grid(wsub / s_final[..., None], FP4_E2M1)
    codes = _sign_mag_code(q, jnp.where(wsub < 0, -1.0, 1.0))
    packed_codes = pack_nibbles(codes.reshape(*w.shape[:-1], -1))
    packed_meta = pack_meta2(k_sel.reshape(*w.shape[:-1], -1))
    return PackedM2XFP(
        codes=packed_codes,
        scale=e8m0_encode(e_stored).reshape(*w.shape[:-1], -1),
        meta=packed_meta,
        kind="weight", group=group, subgroup=subgroup, orig_shape=tuple(w.shape),
    )


def decode_weight_m2xfp(p: PackedM2XFP) -> jax.Array:
    """Dequantize packed Sg-EM weights (PE subgroup scale refinement path)."""
    group, subgroup = p.group, p.subgroup
    n = p.orig_shape[-1]
    ng, n_sub = n // group, group // subgroup
    codes = unpack_nibbles(p.codes).reshape(*p.orig_shape[:-1], ng, n_sub, subgroup)
    mag, sign = _sign_mag_decode(codes)
    k = unpack_meta2(p.meta.reshape(*p.orig_shape[:-1], -1), ng * n_sub)
    k = k.reshape(*p.orig_shape[:-1], ng, n_sub, 1).astype(jnp.float32)
    s = e8m0_decode(p.scale).reshape(*p.orig_shape[:-1], ng, 1, 1)
    dq = mag * sign * (1.0 + k / 4.0) * s
    return dq.reshape(p.orig_shape)
