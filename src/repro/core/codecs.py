"""Codec registry — one format abstraction for the whole stack.

The paper's claims are *comparative* (M2XFP vs MXFP4/NVFP4/SMX4, Tbl. 2/3),
so every layer that speaks a format — fake-quant in the training graph,
packed serving weights, the fused dequant-GEMM kernels, the quantized KV
cache, prequantized checkpoints, and the health telemetry — goes through
one :class:`Codec` record looked up by name instead of per-module
``fmt == "..."`` string chains. Adding a format is one ``register_codec``
call; everything downstream (``quantized_matmul``, ``ServeEngine``,
``serve_bench --fmt``) picks it up.

A codec always provides the fake-quant pair (both operate group-wise along
the **last** axis, like ``repro.core.formats``). The packed serving path
(``encode``/``decode``/``kernel``) and the packed KV path
(``kv_encode``/``kv_decode``/``kv_spec``) are optional — formats without
them can still be fake-quant benchmarked, and asking for a missing path
raises a ``ValueError`` naming the codecs that do support it.

Packed-stream conventions (shared with ``repro.kernels.layout``):

  * ``encode(w)``: (K, N) f32 -> dict of 2-D streams, quantization groups
    along K (the GEMM contraction axis), codes nibble-packed in the
    group-half interleaved kernel layout (K % 32 == 0).
  * ``decode(streams, k, n)``: exact inverse to f32 (K, N) — bit-identical
    to the codec's ``fake_quant_weight`` of the original tensor.
  * ``decode_dtype``: narrowest dtype the decode is *exact* in. bf16 for
    E8M0-scaled codecs (every decoded value fits 8 mantissa bits); f32 for
    NVFP4 (the per-tensor scale is an arbitrary f32).
  * ``kernel(x, streams)``: optional fused dequant-GEMM (Pallas on TPU,
    interpret elsewhere); absent codecs serve through the XLA decode
    mirror in ``repro.models.quant``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .dtypes import (
    FP4_E2M1, exp2int, fp4_code_to_value, fp4_value_to_code, round_to_grid,
)
from .ebw import format_ebw
from .formats import (
    quantize_fp4_fp16scale, quantize_mxfp4, quantize_nvfp4, quantize_smx4,
)
from .m2xfp import (
    quantize_act_m2nvfp4, quantize_act_m2xfp, quantize_weight_m2nvfp4,
    quantize_weight_m2xfp, sg_em_dequant_with_scale,
)
from .packing import (
    group_reshape, pack_meta2, pack_nibbles, unpack_meta2, unpack_nibbles,
)
from .scaling import e8m0_decode, e8m0_encode, shared_scale_exponent

__all__ = [
    "Codec", "PackedTensor", "register_codec", "get_codec", "list_codecs",
    "packed_codecs", "kv_codecs", "kernel_codecs", "validate_packed",
    "validate_packed_tree",
]

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP


# ---------------------------------------------------------------------------
# Codec record + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Codec:
    """One MX-family format: fake-quant always, packed paths optional."""

    name: str
    group: int
    ebw: float
    fake_quant_weight: Callable[[jax.Array], jax.Array]
    fake_quant_act: Callable[[jax.Array], jax.Array]
    # packed serving weights
    encode: Optional[Callable] = None          # (K, N) f32 -> {name: 2-D}
    decode: Optional[Callable] = None          # (streams, k, n) -> f32 (K, N)
    decode_dtype: Any = jnp.bfloat16           # narrowest exact decode dtype
    kernel: Optional[Callable] = None          # fused dequant-GEMM hook
    # packed KV cache
    kv_encode: Optional[Callable] = None       # (..., hd) -> {name: u8}
    kv_decode: Optional[Callable] = None       # inverse -> bf16 (..., hd)
    kv_spec: Optional[Callable] = None         # (b, w, nkv, hd) -> zero page
    # telemetry hints (repro.obs.quant_health)
    scale_kind: str = "e8m0"                   # e8m0 | e4m3 | f16
    scale_sat_bounds: Optional[Tuple[int, int]] = None  # saturated byte bounds
    has_meta: bool = False                     # streams carry 2-bit metadata
    # False when fake_quant_act scales per tensor (nvfp4-style): the online
    # activation quantization then depends on which tokens share a launch,
    # so chunked prefill / batched decode are NOT bit-identical to serving
    # token-by-token (same root cause that rules out a packed KV path)
    act_batch_invariant: bool = True

    @property
    def packed(self) -> bool:
        return self.encode is not None

    @property
    def kv_capable(self) -> bool:
        return self.kv_encode is not None


_REGISTRY: dict[str, Codec] = {}


def register_codec(codec: Codec, overwrite: bool = False) -> Codec:
    """Add a codec to the registry (``overwrite=True`` to replace)."""
    if codec.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"codec {codec.name!r} already registered "
            f"(pass overwrite=True to replace)")
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    """Registry lookup; unknown names raise listing every registered codec."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered codecs: "
            f"{', '.join(list_codecs())}") from None


def list_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def packed_codecs() -> Tuple[str, ...]:
    """Codecs with a packed serving-weight path (encode/decode)."""
    return tuple(n for n in list_codecs() if _REGISTRY[n].packed)


def kv_codecs() -> Tuple[str, ...]:
    """Codecs with a packed KV-cache path."""
    return tuple(n for n in list_codecs() if _REGISTRY[n].kv_capable)


def kernel_codecs() -> Tuple[str, ...]:
    """Codecs with a fused dequant-GEMM kernel hook."""
    return tuple(n for n in list_codecs() if _REGISTRY[n].kernel is not None)


# ---------------------------------------------------------------------------
# Codec-tagged packed pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
class PackedTensor:
    """Packed weight/tensor pytree tagged with its codec name.

    ``streams`` maps stream name -> u8/f32 array; the logical dense shape
    and codec ride in the (static) aux data, so jit/vmap/eval_shape all see
    them as compile-time constants. Children are key-flattened under their
    stream names (``codes``/``scales``/``meta``/...) so checkpoint leaf
    paths and sharding rules see the same names for every codec."""

    def __init__(self, streams: dict, shape, codec: str = "m2xfp"):
        self.streams = dict(streams)
        self.shape = tuple(shape)
        self.codec = codec

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        names = tuple(self.streams)
        return (tuple((k(n), self.streams[n]) for n in names),
                (self.shape, self.codec, names))

    def tree_flatten(self):
        names = tuple(self.streams)
        return (tuple(self.streams[n] for n in names),
                (self.shape, self.codec, names))

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, codec, names = aux
        return cls(dict(zip(names, children)), shape, codec)

    def __getattr__(self, name):   # p.codes / p.scales / p.meta sugar
        try:
            return self.__dict__["streams"][name]
        except KeyError:
            raise AttributeError(name) from None

    def __getitem__(self, key):    # dict-style access for convenience
        if key == "shape":
            return self.shape
        return self.streams[key]

    def __repr__(self):
        return (f"PackedTensor(codec={self.codec!r}, shape={self.shape}, "
                f"streams={list(self.streams)})")


# ---------------------------------------------------------------------------
# Packed-stream integrity validation
# ---------------------------------------------------------------------------

def validate_packed(p: PackedTensor) -> list:
    """Integrity-check one packed tensor's streams against the encoder
    invariants of its codec. Returns a list of human-readable problems
    (empty list = valid).

    Checks (per the OCP Microscaling spec and this repo's encoders):
      * E8M0 scale bytes must lie in [1, 254] — ``repro.core.scaling``
        clamps exponents to [-126, 127], so byte 0 (2^-127, never emitted)
        and byte 255 (reserved/NaN; decodes to inf) cannot be produced by
        any encoder. A byte outside the range means the stream was
        corrupted after packing (bit flip, truncated read, bad DMA).
      * E4M3 scale bytes must not be a NaN encoding (0x7F / 0xFF).
      * Float per-tensor scalars (nvfp4's ``tscale``) must be finite.
      * The code stream must hold exactly two nibbles per logical element.
    """
    import numpy as np
    codec = get_codec(p.codec)
    problems = []
    if not codec.packed:
        return [f"codec {p.codec!r} has no packed path"]
    streams = {name: np.asarray(s) for name, s in p.streams.items()}
    sc = streams.get("scales")
    if sc is not None and sc.dtype == np.uint8:
        if codec.scale_kind == "e8m0":
            bad = (sc < 1) | (sc > 254)
            legal = "[1, 254]"
        elif codec.scale_kind == "e4m3":
            bad = (sc & 0x7F) == 0x7F
            legal = "any non-NaN e4m3 byte"
        else:  # pragma: no cover - no u8-scaled codec with another kind yet
            bad, legal = None, ""
        if bad is not None and bad.any():
            idx = np.argwhere(bad)[0]
            problems.append(
                f"{int(bad.sum())} scale byte(s) outside the legal "
                f"{codec.scale_kind} range {legal} (first at index "
                f"{tuple(int(i) for i in idx)}, byte "
                f"{int(sc[tuple(idx)])})")
    for name, s in streams.items():
        if np.issubdtype(s.dtype, np.floating) and not np.isfinite(
                np.asarray(s, np.float32)).all():
            problems.append(f"non-finite value in float stream {name!r}")
    codes = streams.get("codes")
    if codes is not None:
        import math as _math
        n_elems = _math.prod(p.shape)
        if n_elems and (2 * codes.size) % n_elems != 0:
            problems.append(
                f"code stream holds {2 * codes.size} nibbles, not a "
                f"multiple of the {n_elems} logical elements of shape "
                f"{p.shape}")
    return problems


def validate_packed_tree(tree) -> dict:
    """Run :func:`validate_packed` over every ``PackedTensor`` leaf of a
    parameter tree. Returns {leaf path: [problems]} for invalid leaves only
    (empty dict = every packed stream is intact)."""
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PackedTensor))[0]
    report = {}
    for path, leaf in flat:
        if not isinstance(leaf, PackedTensor):
            continue
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        problems = validate_packed(leaf)
        if problems:
            report[key] = problems
    return report


# ---------------------------------------------------------------------------
# Packed weight encode/decode (XLA mirrors of the kernel layouts)
# ---------------------------------------------------------------------------

def _unpack_codes(codes: jax.Array, k: int, n: int) -> jax.Array:
    """Group-half interleaved u8 (K/2, N) -> int32 sign-mag codes (K, N)."""
    pg = codes.reshape(k // GROUP, 16, n)
    return jnp.concatenate(
        [(pg & 0xF).astype(jnp.int32), (pg >> 4).astype(jnp.int32)], axis=1
    ).reshape(k, n)


def _encode_sgem(w: jax.Array) -> dict:
    from repro.kernels.layout import pack_w_sgem
    return pack_w_sgem(w)


def _decode_sgem(streams: dict, k: int, n: int) -> jax.Array:
    """Sg-EM-2bit decode: fp4 * (1 + meta/4) * 2^(scale-127)."""
    c = _unpack_codes(streams["codes"], k, n)
    mag = fp4_code_to_value(c & 7)
    sign = jnp.where((c & 8) != 0, -1.0, 1.0)
    scales = exp2int(streams["scales"].astype(jnp.int32) - 127)
    meta = streams["meta"]
    fields = jnp.stack(
        [(meta >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.float32)
    mult = 1.0 + fields[:, :, None, :] / 4.0               # (K/32, 4, 1, n)
    w = (mag * sign).reshape(k // GROUP, N_SUB, SUBGROUP, n) * mult \
        * scales[:, None, None, :]
    return w.reshape(k, n)


def _encode_mxfp4(w: jax.Array) -> dict:
    from repro.kernels.layout import pack_w_mxfp4
    return pack_w_mxfp4(w)


def _decode_mxfp4(streams: dict, k: int, n: int) -> jax.Array:
    c = _unpack_codes(streams["codes"], k, n)
    mag = fp4_code_to_value(c & 7)
    sign = jnp.where((c & 8) != 0, -1.0, 1.0)
    scales = exp2int(streams["scales"].astype(jnp.int32) - 127)
    w = (mag * sign).reshape(k // GROUP, GROUP, n) * scales[:, None, :]
    return w.reshape(k, n)


def _encode_nvfp4(w: jax.Array) -> dict:
    from repro.kernels.layout import pack_w_nvfp4
    return pack_w_nvfp4(w)


def _decode_nvfp4(streams: dict, k: int, n: int) -> jax.Array:
    """NVFP4 decode: fp4 * (e4m3 group scale * f32 tensor scale). Exact in
    f32 only — the tensor scale is an arbitrary float."""
    c = _unpack_codes(streams["codes"], k, n)
    mag = fp4_code_to_value(c & 7)
    sign = jnp.where((c & 8) != 0, -1.0, 1.0)
    s8 = jax.lax.bitcast_convert_type(
        streams["scales"], jnp.float8_e4m3fn).astype(jnp.float32)
    s = s8 * streams["tscale"].reshape(())
    s = jnp.where(s == 0, 1.0, s)                          # mirrors encode
    w = (mag * sign).reshape(k // 16, 16, n) * s[:, None, :]
    return w.reshape(k, n)


def _m2xfp_kernel(x: jax.Array, streams: dict, **kw) -> jax.Array:
    from repro.kernels.ops import m2xfp_matmul
    return m2xfp_matmul(x, streams, **kw)


def _mxfp4_kernel(x: jax.Array, streams: dict, **kw) -> jax.Array:
    from repro.kernels.ops import mxfp4_matmul
    return mxfp4_matmul(x, streams, **kw)


# ---------------------------------------------------------------------------
# Packed KV cache paths (paper Sec. 6.4: K/V are right-hand GEMM operands)
# ---------------------------------------------------------------------------

def _kv_encode_sgem(x: jax.Array) -> dict:
    """(..., hd) -> Sg-EM fixed-scale streams (online-cheap; the adaptive
    group-bias search is reserved for offline weight packing)."""
    from repro.obs.quant_health import probe_scaled
    hd = x.shape[-1]
    xg = group_reshape(x.astype(jnp.float32), GROUP)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, "floor")
    s = exp2int(e)
    _, k_sel, _ = sg_em_dequant_with_scale(
        xg, s, SUBGROUP, bits=2, adaptive=False, return_codes=True)
    s_final = (1.0 + k_sel.astype(jnp.float32) / 4.0) * s
    xsub = xg.reshape(*xg.shape[:-1], N_SUB, SUBGROUP)
    probe_scaled("kv_encode", xsub / s_final[..., None], e, k_sel,
                 codec="m2xfp")
    q = round_to_grid(xsub / s_final[..., None], FP4_E2M1)
    mag = fp4_value_to_code(jnp.abs(q))
    codes = jnp.where(xsub < 0, mag | 8, mag).reshape(*x.shape[:-1], hd)
    return {
        "codes": pack_nibbles(codes),
        "scales": e8m0_encode(e[..., 0]),
        "meta": pack_meta2(k_sel.reshape(*x.shape[:-1], -1)),
    }


def _kv_decode_sgem(p: dict) -> jax.Array:
    codes = unpack_nibbles(p["codes"])
    hd = codes.shape[-1]
    mag = fp4_code_to_value(codes & 7)
    sign = jnp.where((codes & 8) != 0, -1.0, 1.0)
    s = e8m0_decode(p["scales"])[..., None]                  # (..., ng, 1)
    k = unpack_meta2(p["meta"], (hd // GROUP) * N_SUB)
    mult = 1.0 + k.astype(jnp.float32) / 4.0
    vals = (mag * sign).reshape(*codes.shape[:-1], hd // GROUP, N_SUB,
                                SUBGROUP)
    out = vals * mult.reshape(*codes.shape[:-1], hd // GROUP, N_SUB, 1) \
        * s[..., None]
    return out.reshape(*codes.shape[:-1], hd).astype(jnp.bfloat16)


def _kv_spec_sgem(batch: int, w: int, nkv: int, hd: int) -> dict:
    return {
        "codes": jnp.zeros((batch, w, nkv, hd // 2), jnp.uint8),
        "scales": jnp.zeros((batch, w, nkv, hd // GROUP), jnp.uint8),
        "meta": jnp.zeros((batch, w, nkv, hd // GROUP), jnp.uint8),
    }


def _kv_encode_mxfp4(x: jax.Array) -> dict:
    """(..., hd) -> plain MXFP4 streams (no metadata byte)."""
    from repro.obs.quant_health import probe_scaled
    hd = x.shape[-1]
    xg = group_reshape(x.astype(jnp.float32), GROUP)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, "floor")
    s = exp2int(e)
    probe_scaled("kv_encode", xg / s, e, None, codec="mxfp4")
    q = round_to_grid(xg / s, FP4_E2M1)
    mag = fp4_value_to_code(jnp.abs(q))
    codes = jnp.where(xg < 0, mag | 8, mag).reshape(*x.shape[:-1], hd)
    return {
        "codes": pack_nibbles(codes),
        "scales": e8m0_encode(e[..., 0]),
    }


def _kv_decode_mxfp4(p: dict) -> jax.Array:
    codes = unpack_nibbles(p["codes"])
    hd = codes.shape[-1]
    mag = fp4_code_to_value(codes & 7)
    sign = jnp.where((codes & 8) != 0, -1.0, 1.0)
    s = e8m0_decode(p["scales"])[..., None]
    vals = (mag * sign).reshape(*codes.shape[:-1], hd // GROUP, GROUP) * s
    return vals.reshape(*codes.shape[:-1], hd).astype(jnp.bfloat16)


def _kv_spec_mxfp4(batch: int, w: int, nkv: int, hd: int) -> dict:
    return {
        "codes": jnp.zeros((batch, w, nkv, hd // 2), jnp.uint8),
        "scales": jnp.zeros((batch, w, nkv, hd // GROUP), jnp.uint8),
    }


# ---------------------------------------------------------------------------
# Built-in codecs (the paper's format matrix)
# ---------------------------------------------------------------------------

register_codec(Codec(
    name="m2xfp", group=32, ebw=format_ebw("m2xfp"),
    fake_quant_weight=quantize_weight_m2xfp,
    fake_quant_act=quantize_act_m2xfp,
    encode=_encode_sgem, decode=_decode_sgem, decode_dtype=jnp.bfloat16,
    kernel=_m2xfp_kernel,
    kv_encode=_kv_encode_sgem, kv_decode=_kv_decode_sgem,
    kv_spec=_kv_spec_sgem,
    scale_kind="e8m0", scale_sat_bounds=(1, 254), has_meta=True))

# Ablation (paper Tbl. 4): weights identical to m2xfp; activations refine
# the subgroup top-1 with an *unclamped* FP6 instead of the 2-bit encoding.
register_codec(Codec(
    name="m2xfp_ideal6", group=32, ebw=format_ebw("m2xfp"),
    fake_quant_weight=quantize_weight_m2xfp,
    fake_quant_act=partial(quantize_act_m2xfp, encoding="ideal"),
    encode=_encode_sgem, decode=_decode_sgem, decode_dtype=jnp.bfloat16,
    kernel=_m2xfp_kernel,
    kv_encode=_kv_encode_sgem, kv_decode=_kv_decode_sgem,
    kv_spec=_kv_spec_sgem,
    scale_kind="e8m0", scale_sat_bounds=(1, 254), has_meta=True))

register_codec(Codec(
    name="m2nvfp4", group=16, ebw=format_ebw("m2nvfp4"),
    fake_quant_weight=quantize_weight_m2nvfp4,
    fake_quant_act=quantize_act_m2nvfp4,
    scale_kind="e4m3", act_batch_invariant=False))

register_codec(Codec(
    name="mxfp4", group=32, ebw=format_ebw("mxfp4"),
    fake_quant_weight=quantize_mxfp4,
    fake_quant_act=quantize_mxfp4,
    encode=_encode_mxfp4, decode=_decode_mxfp4, decode_dtype=jnp.bfloat16,
    kernel=_mxfp4_kernel,
    kv_encode=_kv_encode_mxfp4, kv_decode=_kv_decode_mxfp4,
    kv_spec=_kv_spec_mxfp4,
    scale_kind="e8m0", scale_sat_bounds=(1, 254)))

# NVFP4's element scale is (e4m3 byte) * (per-tensor f32): exact decode
# needs f32, and per-call tensor scales make online KV packing order-
# dependent (chunked vs sequential prefill would diverge) — no KV path.
register_codec(Codec(
    name="nvfp4", group=16, ebw=format_ebw("nvfp4"),
    fake_quant_weight=quantize_nvfp4,
    fake_quant_act=quantize_nvfp4,
    encode=_encode_nvfp4, decode=_decode_nvfp4, decode_dtype=jnp.float32,
    scale_kind="e4m3", scale_sat_bounds=(0, 126),
    act_batch_invariant=False))

register_codec(Codec(
    name="smx4", group=16, ebw=format_ebw("smx4"),
    fake_quant_weight=quantize_smx4,
    fake_quant_act=quantize_smx4))

register_codec(Codec(
    name="fp4", group=32, ebw=format_ebw("fp4_fp16scale"),
    fake_quant_weight=quantize_fp4_fp16scale,
    fake_quant_act=quantize_fp4_fp16scale,
    scale_kind="f16"))
