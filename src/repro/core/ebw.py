"""Equivalent bit width (EBW) accounting — paper Eq. 2.

EBW = B_elem + (B_meta + B_scale) / k

for a group of k elements with B_meta total metadata bits and B_scale shared
scale bits. Used as the x-axis of the DSE Pareto analysis (Figs. 6-7).
"""
from __future__ import annotations

__all__ = ["ebw", "format_ebw"]


def ebw(group: int, elem_bits: float = 4.0, meta_bits: float = 0.0,
        scale_bits: float = 8.0) -> float:
    return elem_bits + (meta_bits + scale_bits) / group


def format_ebw(name: str, **kw) -> float:
    """EBW of the named format. kw: group/subgroup overrides."""
    if name == "mxfp4":
        return ebw(kw.get("group", 32))                        # 4.25
    if name == "nvfp4":
        return ebw(kw.get("group", 16))                        # 4.5
    if name == "smx4":
        # sign(1) + mantissa(2) + pair microexponent (1/2) + 8-bit group scale
        g = kw.get("group", 16)
        return ebw(g, elem_bits=3.5)                           # 4.0
    if name == "fp4_fp16scale":
        return ebw(kw.get("group", 32), scale_bits=16.0)       # 4.5
    if name == "m2xfp":
        g = kw.get("group", 32)
        sg = kw.get("subgroup", 8)
        mb = kw.get("meta_bits_per_subgroup", 2.0)
        return ebw(g, meta_bits=mb * (g // sg))                # 4.5
    if name == "m2nvfp4":
        g = kw.get("group", 16)
        sg = kw.get("subgroup", 4)
        return ebw(g, meta_bits=2.0 * (g // sg))               # 5.0
    raise ValueError(f"unknown format {name!r}")
