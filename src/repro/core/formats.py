"""Baseline MX-family quantizers (fake-quant: quantize -> dequantize).

All operate group-wise along the last axis and return an f32 tensor of the
same shape. These are the paper's comparison formats (Fig. 3, Tbl. 2/3):

  fp4_fp16scale : group FP4 with an exact (FP16-precision) scale amax/6
  mxfp4         : OCP MXFP4 — group 32, E8M0 shared scale (rule configurable)
  nvfp4         : NVIDIA NVFP4 — group 16, FP8 E4M3 scale + f32 tensor scale
  smx4          : Shared Microexponents (SMX4) — group 16, INT3 elements,
                  1-bit micro-exponent per pair of elements
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .dtypes import FP4_E2M1, FP8_E4M3, exp2int, round_to_grid
from .packing import group_reshape, group_unreshape
from .scaling import shared_scale_exponent

__all__ = [
    "quantize_fp4_fp16scale", "quantize_mxfp4", "quantize_nvfp4",
    "quantize_smx4", "mxfp4_components",
]


def _group_amax(xg: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(xg), axis=-1, keepdims=True)


@partial(jax.jit, static_argnames=("group",))
def quantize_fp4_fp16scale(x: jax.Array, group: int = 32) -> jax.Array:
    """Group FP4 with a precise scale s = amax / 6 (the 'FP4' line of Fig. 3)."""
    xg = group_reshape(x.astype(jnp.float32), group)
    s = _group_amax(xg) / FP4_E2M1.max_value
    s = jnp.where(s == 0, 1.0, s)
    q = round_to_grid(xg / s, FP4_E2M1)
    return group_unreshape(q * s).astype(x.dtype)


@partial(jax.jit, static_argnames=("group", "rule"))
def quantize_mxfp4(x: jax.Array, group: int = 32, rule: str = "floor") -> jax.Array:
    """OCP MXFP4: E8M0 shared scale (default floor rule), FP4 E2M1 elements."""
    xg = group_reshape(x.astype(jnp.float32), group)
    e = shared_scale_exponent(_group_amax(xg), rule)
    s = exp2int(e)
    q = round_to_grid(xg / s, FP4_E2M1)
    return group_unreshape(q * s).astype(x.dtype)


def mxfp4_components(x: jax.Array, group: int = 32, rule: str = "floor"):
    """MXFP4 split into (fp4_values_grouped, scale_exponent) — building block
    for the M2XFP encoders. fp4 values are the *unscaled* grid values; the
    dequantized tensor is fp4 * 2^E."""
    xg = group_reshape(x.astype(jnp.float32), group)
    e = shared_scale_exponent(_group_amax(xg), rule)
    s = exp2int(e)
    q = round_to_grid(xg / s, FP4_E2M1)
    return q, e


@partial(jax.jit, static_argnames=("group",))
def quantize_nvfp4(x: jax.Array, group: int = 16) -> jax.Array:
    """NVFP4: FP8 (E4M3) group scale + f32 per-tensor scale, FP4 elements.

    Tensor scale maps the largest group-scale into E4M3 range:
      t  = amax_tensor / (448 * 6)
      s8 = RTNE_e4m3(amax_group / (6 t));  element scale = s8 * t
    """
    xf = x.astype(jnp.float32)
    xg = group_reshape(xf, group)
    amax_t = jnp.max(jnp.abs(xf))
    t = amax_t / (FP8_E4M3.max_value * FP4_E2M1.max_value)
    t = jnp.where(t == 0, 1.0, t)
    s8 = round_to_grid(_group_amax(xg) / (FP4_E2M1.max_value * t), FP8_E4M3)
    s = s8 * t
    s = jnp.where(s == 0, 1.0, s)
    q = round_to_grid(xg / s, FP4_E2M1)
    return group_unreshape(q * s).astype(x.dtype)


@partial(jax.jit, static_argnames=("group", "pair"))
def quantize_smx4(x: jax.Array, group: int = 16, pair: int = 2) -> jax.Array:
    """SMX4 (Shared Microexponents): two-level block floating point.

    Group of 16 shares an 8-bit scale 2^E; each pair of neighbours shares a
    1-bit micro-exponent b in {0, 1} selecting scale 2^(E-b). Elements are
    symmetric INT3 (range [-3, 3]).  E is chosen so the group max maps to 3.
    """
    int3_max = 3.0
    xg = group_reshape(x.astype(jnp.float32), group)
    amax = _group_amax(xg)
    safe = jnp.maximum(amax, 1e-30)
    # ceil(log2(amax/3)) so amax/2^E <= 3 (no clipping of the block max).
    e = jnp.ceil(jnp.log2(safe / int3_max))
    e = jnp.where(amax == 0, 0.0, e)
    s = exp2int(e.astype(jnp.int32))
    # pairs: (..., n_groups, group) -> (..., n_groups, group/pair, pair)
    xp = xg.reshape(*xg.shape[:-1], group // pair, pair)
    pmax = jnp.max(jnp.abs(xp), axis=-1, keepdims=True)
    # use the finer scale 2^(E-1) when the pair still fits into [-3, 3]
    b = (pmax <= int3_max * s[..., None] / 2).astype(jnp.int32)
    sp = s[..., None] * exp2int(-b)
    q = jnp.clip(jnp.round(xp / sp), -int3_max, int3_max)
    dq = (q * sp).reshape(xg.shape)
    return group_unreshape(dq).astype(x.dtype)
