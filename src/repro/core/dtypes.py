"""Low-bit floating-point grids used by MX formats.

All rounding is round-to-nearest-even (RTNE), implemented by scaling into the
correct binade (exact, via frexp bit manipulation) and using ``jnp.round`` whose
half-way behaviour is ties-to-even. Grid-index parity equals mantissa parity
within a binade, so integer-RTNE == floating-point-RTNE on these grids.

Formats:
  FP4 E2M1  (bias 1): magnitudes {0, .5, 1, 1.5, 2, 3, 4, 6};   P=4, M=6
  FP6 E2M3  (bias 1): 32 magnitudes, max 7.5, subnormal step 1/8
  FP8 E4M3  (bias 7): max 448 (NVFP4 scale format)
  E8M0      (bias 127): power-of-two scale, value 2^E, E in [-127, 127]
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FloatSpec", "FP4_E2M1", "FP6_E2M3", "FP8_E4M3",
    "round_to_grid", "floor_log2", "exp2int", "fp4_code_to_value", "fp4_value_to_code",
    "fp6_code_to_value", "fp6_value_to_code",
    "FP4_MAG_VALUES", "FP6_MAG_VALUES",
]


@dataclasses.dataclass(frozen=True)
class FloatSpec:
    """A miniature sign/exponent/mantissa float format (finite grid)."""

    name: str
    exp_bits: int
    man_bits: int
    bias: int
    # E4M3 reserves mantissa=0b111 at the top binade for NaN -> max 448,
    # not the generic 480. None = generic formula.
    max_value_override: float | None = None

    @property
    def emax(self) -> int:
        """Largest true (unbiased) exponent of a normal number."""
        return (2 ** self.exp_bits - 1) - self.bias

    @property
    def emin(self) -> int:
        """True exponent of the smallest normal / the subnormal binade."""
        return 1 - self.bias

    @property
    def max_value(self) -> float:
        if self.max_value_override is not None:
            return self.max_value_override
        return float(2.0 ** self.emax * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def max_pow2(self) -> float:
        """Largest representable power of two (the OCP 'P' constant)."""
        return float(2.0 ** self.emax)

    @property
    def n_mag_codes(self) -> int:
        """Number of distinct magnitude codes (exp+man bit patterns)."""
        return 2 ** (self.exp_bits + self.man_bits)

    def magnitude_grid(self) -> np.ndarray:
        """All representable magnitudes in code order (monotone increasing)."""
        codes = np.arange(self.n_mag_codes)
        e = codes >> self.man_bits
        m = codes & (2 ** self.man_bits - 1)
        sub = e == 0
        vals = np.where(
            sub,
            2.0 ** self.emin * (m / 2.0 ** self.man_bits),
            2.0 ** (e - self.bias) * (1.0 + m / 2.0 ** self.man_bits),
        )
        return vals.astype(np.float64)


FP4_E2M1 = FloatSpec("fp4_e2m1", exp_bits=2, man_bits=1, bias=1)
FP6_E2M3 = FloatSpec("fp6_e2m3", exp_bits=2, man_bits=3, bias=1)
FP8_E4M3 = FloatSpec("fp8_e4m3", exp_bits=4, man_bits=3, bias=7,
                     max_value_override=448.0)

# Static grids (code order == magnitude order — both formats are monotone).
FP4_MAG_VALUES = jnp.asarray(FP4_E2M1.magnitude_grid(), dtype=jnp.float32)  # (8,)
FP6_MAG_VALUES = jnp.asarray(FP6_E2M3.magnitude_grid(), dtype=jnp.float32)  # (32,)

assert FP4_E2M1.max_value == 6.0 and FP4_E2M1.max_pow2 == 4.0
assert FP6_E2M3.max_value == 7.5
assert FP8_E4M3.max_value == 448.0


def exp2int(e: jax.Array) -> jax.Array:
    """Exact 2^e (f32) for integer e in [-126, 127], via exponent-field
    construction — ``jnp.exp2`` is not bit-exact on all backends, which
    would break exact power-of-two scaling."""
    bits = (jnp.clip(e, -126, 127).astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def floor_log2(x: jax.Array) -> jax.Array:
    """Exact floor(log2(|x|)) via frexp (no log rounding error). x>0 assumed
    where used; returns garbage for 0 (caller masks)."""
    _, e = jnp.frexp(x)  # x = m * 2^e with m in [0.5, 1)
    return e - 1


@partial(jax.jit, static_argnames=("spec", "saturate"))
def round_to_grid(x: jax.Array, spec: FloatSpec, saturate: bool = True) -> jax.Array:
    """RTNE-round ``x`` onto the magnitude grid of ``spec`` (sign preserved).

    Matches IEEE-style RTNE with saturation to +-max_value (OCP MX behaviour).
    """
    x = x.astype(jnp.float32)
    ax = jnp.abs(x)
    # True exponent of each element, clamped to the format's binade range.
    e = floor_log2(jnp.maximum(ax, jnp.float32(2.0 ** spec.emin)))
    e = jnp.clip(e, spec.emin, spec.emax)
    step = exp2int(e - spec.man_bits)
    q = jnp.round(ax / step) * step  # jnp.round is ties-to-even
    if saturate:
        q = jnp.minimum(q, spec.max_value)
    out = jnp.sign(x) * q
    # Preserve signed zero semantics irrelevant here; map -0.0 -> 0.0 * sign.
    return out.astype(jnp.float32)


# --- code <-> value conversions (needed for the bias-clamp metadata encoding) ---

def fp4_value_to_code(v: jax.Array) -> jax.Array:
    """Magnitude (exact grid value) -> 3-bit E2M1 code. v must be on-grid, >=0."""
    # searchsorted on the static 8-entry grid; exact because v is on-grid.
    return jnp.searchsorted(FP4_MAG_VALUES, v.astype(jnp.float32)).astype(jnp.int32)


def fp4_code_to_value(c: jax.Array) -> jax.Array:
    return FP4_MAG_VALUES[jnp.clip(c, 0, 7)]


def fp6_value_to_code(v: jax.Array) -> jax.Array:
    """Magnitude (exact grid value) -> 5-bit E2M3 code. v must be on-grid, >=0."""
    return jnp.searchsorted(FP6_MAG_VALUES, v.astype(jnp.float32)).astype(jnp.int32)


def fp6_code_to_value(c: jax.Array) -> jax.Array:
    return FP6_MAG_VALUES[jnp.clip(c, 0, 31)]
