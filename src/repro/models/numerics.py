"""Matmul dtype policy.

TPU target: bf16 x bf16 -> f32-accumulated MXU dots (what the kernels and
the roofline model assume). The XLA *CPU runtime* in this container cannot
execute BF16xBF16=F32 dot thunks, so executed paths (tests, benchmarks,
examples) upcast operands to f32. The dry-run — which only lowers+compiles —
sets REPRO_FAITHFUL_DOTS=1 so the compiled HLO keeps true bf16 operand
widths (the memory-roofline term depends on them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import envflags

__all__ = ["faithful_dots", "bf16_tp_reduce", "dot_f32acc", "einsum_f32acc"]


def faithful_dots() -> bool:
    return (envflags.get_bool("REPRO_FAITHFUL_DOTS")
            or jax.default_backend() == "tpu")


def bf16_tp_reduce() -> bool:
    """Perf lever (EXPERIMENTS.md §Perf): emit bf16 dot outputs so the
    GSPMD tensor-parallel partial-sum all-reduces move half the bytes
    (standard production trade: bf16 reduction of activations)."""
    return envflags.get_bool("REPRO_BF16_TP_REDUCE")


def dot_f32acc(x: jax.Array, w: jax.Array, dims) -> jax.Array:
    """dot_general with f32 accumulation; CPU-executable fallback."""
    if faithful_dots():
        out = jnp.bfloat16 if bf16_tp_reduce() else jnp.float32
        return jax.lax.dot_general(x, w, dims, preferred_element_type=out)
    return jax.lax.dot_general(
        x.astype(jnp.float32), w.astype(jnp.float32), dims)


def einsum_f32acc(eq: str, *args) -> jax.Array:
    if faithful_dots():
        out = jnp.bfloat16 if bf16_tp_reduce() else jnp.float32
        return jnp.einsum(eq, *args, preferred_element_type=out)
    return jnp.einsum(eq, *[a.astype(jnp.float32) for a in args])
