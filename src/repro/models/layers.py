"""Shared model layers: norms, rotary embeddings, MLPs, embedding tables."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import init_linear, quantized_matmul

__all__ = [
    "rms_norm", "init_rms_norm", "rope_freqs", "apply_rope", "softcap",
    "init_mlp", "mlp_apply", "init_embedding",
]


def init_rms_norm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, ff, dtype=dtype),
        "up": init_linear(k2, d, ff, dtype=dtype),
        "down": init_linear(k3, ff, d, dtype=dtype),
    }


def mlp_apply(p: dict, x: jax.Array, quant: str = "none",
              fmt: str = "m2xfp") -> jax.Array:
    g = quantized_matmul(x, p["gate"], quant, fmt)
    u = quantized_matmul(x, p["up"], quant, fmt)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return quantized_matmul(h, p["down"], quant, fmt)


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
