"""Quantization-aware linear layers — the paper's technique as a first-class
feature of the model substrate.

Modes (ModelConfig.quant):
  none  : plain bf16/f32 GEMM.
  qat   : fake-quant with straight-through estimator on weights and
          activations — W4A4 simulation inside the training graph.
  serve : weights live in HBM as *packed* codec streams (u8 codes + scale
          [+ meta]); decode happens inline before the GEMM (this is the TPU
          analogue of the paper's PE decode path, and what the roofline
          memory term sees). Activations are fake-quantized online with the
          same codec (the quantization engine).

Every format decision goes through the codec registry
(``repro.core.codecs``): ``fake_quant_weight(w, fmt)`` /
``fake_quant_act(x, fmt)`` look the codec up by name, ``pack_serving_weight``
produces a codec-tagged :class:`PackedTensor`, and the serve GEMM dispatches
on the *tensor's* codec — the fused Pallas kernel when the codec has one and
the shape tiles (``serve_matmul_backend``), the pure-XLA decode mirror
otherwise. For E8M0-scaled codecs both sides are numerically identical
(every decoded value is exact in bf16); REPRO_SERVE_KERNEL=xla|pallas forces
one side (docs/kernels.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.codecs import (
    PackedTensor, get_codec, kernel_codecs, packed_codecs,
)

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

# Back-compat alias: the serve/obs/bench layers predating the codec registry
# spell the packed pytree "PackedWeight".
PackedWeight = PackedTensor

__all__ = [
    "fake_quant_weight", "fake_quant_act", "ste", "pack_serving_weight",
    "decode_serving_weight", "quantized_matmul", "serve_matmul_backend",
    "init_linear", "QLinear", "PackedTensor", "PackedWeight",
]


def ste(x: jax.Array, qx: jax.Array) -> jax.Array:
    """Straight-through estimator: forward qx, gradient of identity."""
    return x + jax.lax.stop_gradient(qx - x)


def fake_quant_weight(w: jax.Array, fmt: str = "m2xfp") -> jax.Array:
    """Weight fake-quant along the contraction (first) axis."""
    codec = get_codec(fmt)
    wt = w.reshape(w.shape[0], -1).T        # (out, in): groups along in-dim
    return codec.fake_quant_weight(wt).T.reshape(w.shape)


def fake_quant_act(x: jax.Array, fmt: str = "m2xfp") -> jax.Array:
    """Activation fake-quant along the last (contraction) axis."""
    return get_codec(fmt).fake_quant_act(x)


# ---------------------------------------------------------------------------
# Serving path: packed weights resident in HBM at the codec's EBW
# ---------------------------------------------------------------------------

def _tail_streams(p: PackedTensor) -> tuple:
    """Names of streams laid out (rows, *weight-tail) — i.e. everything but
    per-tensor scalars like nvfp4's ``tscale``."""
    tail = p.shape[1:]
    return tuple(name for name, s in p.streams.items()
                 if s.ndim == len(p.shape) and s.shape[1:] == tail)


def pack_serving_weight(w: jax.Array, fmt: str = "m2xfp") -> PackedTensor:
    """(K, N...) weight -> packed codec streams, groups along K (axis 0).

    For m2xfp: codes u8 (K/2, N...) group-half interleaved nibbles (kernel
    layout), scales u8 (K/32, N...), meta u8 (K/32, N...). Other codecs
    define their own streams; per-tensor scalars keep their 2-D shape."""
    codec = get_codec(fmt)
    if not codec.packed:
        raise ValueError(
            f"codec {fmt!r} has no packed serving path; packable codecs: "
            f"{', '.join(packed_codecs())}")
    k = w.shape[0]
    tail = w.shape[1:]
    w2 = w.reshape(k, -1)
    n = w2.shape[1]
    streams = {}
    for name, s in codec.encode(w2).items():
        if s.ndim == 2 and s.shape[1] == n:
            streams[name] = s.reshape(s.shape[0], *tail)
        else:
            streams[name] = s                          # per-tensor scalar
    return PackedTensor(streams, tuple(w.shape), fmt)


def decode_serving_weight(p: PackedTensor, dtype=None) -> jax.Array:
    """Inline decode of packed streams -> weight (K, N...) in the codec's
    exact dtype (bf16 for E8M0-scaled codecs, f32 for nvfp4) unless
    ``dtype`` overrides it. Pure-XLA mirror of the kernel decode.

    REPRO_GATHER_PACKED=1 (perf lever): constrain the u8 streams to be
    replicated along the weight-shard ('fsdp') axis *before* decoding, so
    GSPMD all-gathers the packed codes instead of 16-bit decoded weights
    (3.55x less wire traffic for the serve path's FSDP gathers)."""
    from repro.core import envflags
    codec = get_codec(p.codec)
    tail_names = _tail_streams(p)
    if envflags.get_bool("REPRO_GATHER_PACKED"):
        from repro.distributed.sharding import constrain
        streams = dict(p.streams)
        for name in tail_names:
            s = streams[name]
            axes = tuple(None if i != s.ndim - 1 else "mlp"
                         for i in range(s.ndim))
            streams[name] = constrain(s, axes)
        p = PackedTensor(streams, p.shape, p.codec)
    shape = p.shape
    k = shape[0]
    n = math.prod(shape[1:])
    streams2d = {name: (s.reshape(s.shape[0], -1) if name in tail_names
                        else s)
                 for name, s in p.streams.items()}
    w = codec.decode(streams2d, k, n)
    return w.reshape(shape).astype(dtype or codec.decode_dtype)


# ---------------------------------------------------------------------------
# The quantized linear primitive used by every model block
# ---------------------------------------------------------------------------

def _pallas_tiles(k: int, n: int) -> bool:
    """True when (K, N) satisfy the packed-matmul alignment constraints with
    the default (bm, bn, bk) = (128, 128, 512) blocks: bk = min(512, K)
    must be a multiple of 32 dividing K, and N must be a multiple of the
    128-lane tile (kernels/ops.py) — interpret mode tolerates narrower N,
    Mosaic does not, and the dispatcher must be safe on real TPUs. The row
    dim M is padded by the kernel wrapper."""
    if k % 32 or (k > 512 and k % 512):
        return False
    return n % 128 == 0


def serve_matmul_backend() -> str:
    """Dispatch rule for the serve-path GEMM (documented in docs/kernels.md):

      REPRO_SERVE_KERNEL=xla     always use the pure-XLA decode mirror
      REPRO_SERVE_KERNEL=pallas  prefer the codec's fused kernel (interpret
                                 mode off-TPU — slow, for validation)
      unset / auto               Pallas on a TPU backend, XLA elsewhere

    Either Pallas choice still requires a codec kernel hook
    (``kernel_codecs()``) and a weight satisfying ``_pallas_tiles``;
    everything else falls back to the XLA mirror.
    """
    from repro.core import envflags
    mode = envflags.get_str("REPRO_SERVE_KERNEL")
    if mode in ("xla", "pallas"):
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _serve_matmul(x: jax.Array, w: PackedTensor, dims) -> jax.Array:
    """Packed-weight GEMM: fake-quantize the activations online with the
    weight's codec, then contract against the packed streams. On TPU,
    codecs with a kernel hook feed the fused dequant-GEMM Pallas kernel
    (weights never rematerialize in bf16 in HBM); otherwise the XLA mirror
    decodes inline.

    Observability (REPRO_OBS, checked at TRACE time so the disabled graph
    is byte-identical): the ``health`` pillar traces clip/scale-saturation/
    meta-mode reductions over the online-quantized activations, drained
    host-side via ``jax.debug.callback`` (asynchronous — no extra syncs on
    the launch); the ``metrics`` pillar counts which backend each GEMM
    call site dispatched to, labeled by codec."""
    from repro import obs
    from .numerics import dot_f32acc
    codec = get_codec(w.codec)
    obs.quant_health.probe_act(x, site="serve_gemm", codec=codec.name)
    xq = codec.fake_quant_act(x.astype(jnp.float32)).astype(jnp.bfloat16)
    k = w.shape[0]
    n = math.prod(w.shape[1:])
    use_pallas = (serve_matmul_backend() == "pallas"
                  and codec.kernel is not None and _pallas_tiles(k, n))
    if obs.enabled():
        obs.counter(
            "repro_serve_gemm_traces_total",
            "serve GEMM call sites traced, by dispatched backend").inc(
            backend="pallas" if use_pallas else "xla", codec=codec.name,
            k=k, n=n)
    if use_pallas:
        with obs.span("trace.serve_matmul", cat="trace", backend="pallas",
                      codec=codec.name, k=k, n=n):
            streams = {name: w[name].reshape(w[name].shape[0], n)
                       for name in _tail_streams(w)}
            for name, s in w.streams.items():
                streams.setdefault(name, s)            # per-tensor scalars
            out = codec.kernel(xq.reshape(-1, k), streams)
        return out.reshape(*x.shape[:-1], *w.shape[1:]).astype(x.dtype)
    with obs.span("trace.serve_matmul", cat="trace", backend="xla",
                  codec=codec.name, k=k, n=n):
        wd = decode_serving_weight(w)
        out = dot_f32acc(xq.astype(wd.dtype), wd, dims).astype(x.dtype)
    return out


def quantized_matmul(x: jax.Array, w, quant: str, fmt: str = "m2xfp",
                     precision=None) -> jax.Array:
    """x (..., K) @ w (K, N...) under the configured quantization mode.

    ``w`` is a dense array for none/qat, a PackedTensor for serve (the
    packed tensor carries its own codec tag — ``fmt`` applies to the dense
    fake-quant modes)."""
    from .numerics import dot_f32acc
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    if quant == "serve" and isinstance(w, PackedTensor):
        return _serve_matmul(x, w, dims)
    if quant == "qat":
        wq = ste(w, fake_quant_weight(w.astype(jnp.float32), fmt).astype(w.dtype))
        xq = ste(x, fake_quant_act(x.astype(jnp.float32), fmt).astype(x.dtype))
        return dot_f32acc(xq, wq, dims).astype(x.dtype)
    return dot_f32acc(x, w, dims).astype(x.dtype)


def init_linear(key, d_in: int, d_out, scale: float | None = None,
                dtype=jnp.bfloat16) -> jax.Array:
    """Truncated-normal init, fan-in scaled. d_out may be a tuple."""
    shape = (d_in, *d_out) if isinstance(d_out, tuple) else (d_in, d_out)
    std = scale if scale is not None else d_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


class QLinear:
    """Namespace of helpers for (de)quantizing whole param trees at
    serve-packing time."""

    @staticmethod
    def pack_tree(params, predicate, fmt: str = "m2xfp"):
        """Replace every weight leaf selected by ``predicate(path)`` with its
        packed representation. Paths are '/'-joined key tuples."""
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        treedef = jax.tree_util.tree_structure(params)
        out = []
        for path, leaf in flat:
            spath = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
            if predicate(spath, leaf):
                out.append(pack_serving_weight(leaf.astype(jnp.float32), fmt))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)
