"""Quantization-aware linear layers — the paper's technique as a first-class
feature of the model substrate.

Modes (ModelConfig.quant):
  none  : plain bf16/f32 GEMM.
  qat   : fake-quant with straight-through estimator on weights (Sg-EM) and
          activations (Elem-EM) — W4A4 simulation inside the training graph.
  serve : weights live in HBM as *packed* M2XFP streams (u8 codes + scale +
          meta = 4.5 bits/elem); decode happens inline before the GEMM (this
          is the TPU analogue of the paper's PE decode path, and what the
          roofline memory term sees). Activations are Elem-EM fake-quantized
          online (the quantization engine).

The serve GEMM dispatches per backend (``serve_matmul_backend``): on TPU the
packed streams feed the fused dequant-GEMM Pallas kernel in
kernels/m2xfp_matmul.py; elsewhere the pure-XLA mirror below decodes inline.
Both are numerically identical (every decoded value is exact in bf16);
REPRO_SERVE_KERNEL=xla|pallas forces one side (docs/kernels.md).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.formats import (
    quantize_fp4_fp16scale, quantize_mxfp4, quantize_nvfp4, quantize_smx4,
)
from repro.core.m2xfp import quantize_act_m2xfp, quantize_weight_m2xfp

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

__all__ = [
    "fake_quant_weight", "fake_quant_act", "ste", "pack_serving_weight",
    "decode_serving_weight", "quantized_matmul", "serve_matmul_backend",
    "init_linear", "QLinear",
]


def ste(x: jax.Array, qx: jax.Array) -> jax.Array:
    """Straight-through estimator: forward qx, gradient of identity."""
    return x + jax.lax.stop_gradient(qx - x)


def fake_quant_weight(w: jax.Array, fmt: str = "m2xfp") -> jax.Array:
    """Weight fake-quant along the contraction (first) axis."""
    wt = w.reshape(w.shape[0], -1).T        # (out, in): groups along in-dim
    if fmt in ("m2xfp", "m2xfp_ideal6"):   # ideal6 differs on acts only
        q = quantize_weight_m2xfp(wt)
    elif fmt == "mxfp4":
        q = quantize_mxfp4(wt)
    elif fmt == "nvfp4":
        q = quantize_nvfp4(wt)
    elif fmt == "smx4":
        q = quantize_smx4(wt)
    elif fmt == "fp4":
        q = quantize_fp4_fp16scale(wt)
    else:
        raise ValueError(fmt)
    return q.T.reshape(w.shape)


def fake_quant_act(x: jax.Array, fmt: str = "m2xfp") -> jax.Array:
    """Activation fake-quant along the last (contraction) axis."""
    if fmt == "m2xfp":
        return quantize_act_m2xfp(x)
    if fmt == "m2xfp_ideal6":      # ablation: unclamped FP6 replacement
        return quantize_act_m2xfp(x, encoding="ideal")
    if fmt == "mxfp4":
        return quantize_mxfp4(x)
    if fmt == "nvfp4":
        return quantize_nvfp4(x)
    if fmt == "smx4":
        return quantize_smx4(x)
    if fmt == "fp4":
        return quantize_fp4_fp16scale(x)
    raise ValueError(fmt)


# ---------------------------------------------------------------------------
# Serving path: packed weights (4.5 bits/elem resident in HBM)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
class PackedWeight:
    """Packed M2XFP weight pytree (shape kept static for jit). Children are
    key-flattened as codes/scales/meta so sharding rules see their names."""

    def __init__(self, codes, scales, meta, shape):
        self.codes, self.scales, self.meta = codes, scales, meta
        self.shape = tuple(shape)

    def tree_flatten_with_keys(self):
        k = jax.tree_util.GetAttrKey
        return ((k("codes"), self.codes), (k("scales"), self.scales),
                (k("meta"), self.meta)), self.shape

    def tree_flatten(self):
        return (self.codes, self.scales, self.meta), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux)

    def __getitem__(self, k):  # dict-style access for convenience
        return getattr(self, k)


def pack_serving_weight(w: jax.Array) -> "PackedWeight":
    """(K, N...) weight -> packed M2XFP streams, groups along K (axis 0).

    codes u8 (K/2, N...): group-half interleaved nibbles (kernel layout)
    scales u8 (K/32, N...), meta u8 (K/32, N...)
    """
    from repro.kernels.layout import pack_w_sgem
    k = w.shape[0]
    w2 = w.reshape(k, -1)
    p = pack_w_sgem(w2)
    tail = w.shape[1:]
    return PackedWeight(
        codes=p["codes"].reshape(k // 2, *tail),
        scales=p["scales"].reshape(k // GROUP, *tail),
        meta=p["meta"].reshape(k // GROUP, *tail),
        shape=tuple(w.shape),
    )


def decode_serving_weight(p: "PackedWeight") -> jax.Array:
    """Inline decode of packed streams -> bf16 weight (K, N...).

    Pure-XLA mirror of the Pallas decode (exact: every decoded value fits in
    bf16's 8-bit mantissa).

    REPRO_GATHER_PACKED=1 (perf lever): constrain the u8 streams to be
    replicated along the weight-shard ('fsdp') axis *before* decoding, so
    GSPMD all-gathers 4.5-bit codes instead of 16-bit decoded weights
    (3.55x less wire traffic for the serve path's FSDP gathers)."""
    import os
    if os.environ.get("REPRO_GATHER_PACKED", "") == "1":
        from repro.distributed.sharding import constrain
        ndim = p["codes"].ndim
        axes = (None,) + ("mlp",) * 0 + tuple(
            "mlp" if i == ndim - 1 else None for i in range(1, ndim))
        p = PackedWeight(
            constrain(p.codes, axes), constrain(p.scales, axes),
            constrain(p.meta, axes), p.shape)
    shape = p["shape"]
    k = shape[0]
    codes = p["codes"].reshape(k // 2, -1)
    n = codes.shape[-1]
    pg = codes.reshape(k // GROUP, 16, n)
    c = jnp.concatenate(
        [(pg & 0xF).astype(jnp.int32), (pg >> 4).astype(jnp.int32)], axis=1
    ).reshape(k, n)
    from repro.core.dtypes import fp4_code_to_value
    mag = fp4_code_to_value(c & 7)
    sign = jnp.where((c & 8) != 0, -1.0, 1.0)
    from repro.core.dtypes import exp2int
    scales = exp2int(p["scales"].reshape(k // GROUP, n).astype(jnp.int32) - 127)
    meta = p["meta"].reshape(k // GROUP, n)
    fields = jnp.stack(
        [(meta >> (2 * j)) & 0x3 for j in range(N_SUB)], axis=1
    ).astype(jnp.float32)
    mult = 1.0 + fields[:, :, None, :] / 4.0               # (K/32, 4, 1, n)
    w = (mag * sign).reshape(k // GROUP, N_SUB, SUBGROUP, n) * mult \
        * scales[:, None, None, :]
    return w.reshape(shape).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# The quantized linear primitive used by every model block
# ---------------------------------------------------------------------------

def _pallas_tiles(k: int, n: int) -> bool:
    """True when (K, N) satisfy the m2xfp_matmul alignment constraints with
    the default (bm, bn, bk) = (128, 128, 512) blocks: bk = min(512, K)
    must be a multiple of 32 dividing K, and N must be a multiple of the
    128-lane tile (kernels/ops.py) — interpret mode tolerates narrower N,
    Mosaic does not, and the dispatcher must be safe on real TPUs. The row
    dim M is padded by the kernel wrapper."""
    if k % 32 or (k > 512 and k % 512):
        return False
    return n % 128 == 0


def serve_matmul_backend() -> str:
    """Dispatch rule for the serve-path GEMM (documented in docs/kernels.md):

      REPRO_SERVE_KERNEL=xla     always use the pure-XLA decode mirror
      REPRO_SERVE_KERNEL=pallas  prefer kernels/m2xfp_matmul (interpret
                                 mode off-TPU — slow, for validation)
      unset / auto               Pallas on a TPU backend, XLA elsewhere

    Either Pallas choice still requires the weight to satisfy
    ``_pallas_tiles``; untileable shapes fall back to the XLA mirror.
    """
    import os
    mode = os.environ.get("REPRO_SERVE_KERNEL", "auto")
    if mode in ("xla", "pallas"):
        return mode
    if mode != "auto":
        raise ValueError(
            f"REPRO_SERVE_KERNEL={mode!r}: expected 'xla', 'pallas' or "
            f"'auto'")
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _serve_matmul(x: jax.Array, w: "PackedWeight", dims) -> jax.Array:
    """Packed-weight GEMM: Elem-EM fake-quantize the activations online,
    then contract against the packed Sg-EM streams. On TPU the streams feed
    the fused dequant-GEMM Pallas kernel (weights never rematerialize in
    bf16 in HBM); on CPU the XLA mirror decodes inline (numerically
    identical — every decoded value is exact in bf16).

    Observability (REPRO_OBS, checked at TRACE time so the disabled graph
    is byte-identical): the ``health`` pillar traces clip/scale-saturation/
    meta-mode reductions over the online-quantized activations, drained
    host-side via ``jax.debug.callback`` (asynchronous — no extra syncs on
    the launch); the ``metrics`` pillar counts which backend each GEMM
    call site dispatched to."""
    from repro import obs
    from .numerics import dot_f32acc
    obs.quant_health.probe_act(x, site="serve_gemm")
    xq = fake_quant_act(x.astype(jnp.float32), "m2xfp").astype(jnp.bfloat16)
    k = w.shape[0]
    n = 1
    for d in w.shape[1:]:
        n *= d
    use_pallas = serve_matmul_backend() == "pallas" and _pallas_tiles(k, n)
    if obs.enabled():
        obs.counter(
            "repro_serve_gemm_traces_total",
            "serve GEMM call sites traced, by dispatched backend").inc(
            backend="pallas" if use_pallas else "xla", k=k, n=n)
    if use_pallas:
        from repro.kernels import m2xfp_matmul
        with obs.span("trace.serve_matmul", cat="trace", backend="pallas",
                      k=k, n=n):
            streams = {"codes": w.codes.reshape(k // 2, n),
                       "scales": w.scales.reshape(k // GROUP, n),
                       "meta": w.meta.reshape(k // GROUP, n)}
            out = m2xfp_matmul(xq.reshape(-1, k), streams)
        return out.reshape(*x.shape[:-1], *w.shape[1:]).astype(x.dtype)
    with obs.span("trace.serve_matmul", cat="trace", backend="xla",
                  k=k, n=n):
        wd = decode_serving_weight(w)
        out = dot_f32acc(xq, wd, dims).astype(x.dtype)
    return out


def quantized_matmul(x: jax.Array, w, quant: str, fmt: str = "m2xfp",
                     precision=None) -> jax.Array:
    """x (..., K) @ w (K, N...) under the configured quantization mode.

    ``w`` is a dense array for none/qat, a PackedWeight for serve."""
    from .numerics import dot_f32acc
    dims = (((x.ndim - 1,), (0,)), ((), ()))
    if quant == "serve" and isinstance(w, PackedWeight):
        return _serve_matmul(x, w, dims)
    if quant == "qat":
        wq = ste(w, fake_quant_weight(w.astype(jnp.float32), fmt).astype(w.dtype))
        xq = ste(x, fake_quant_act(x.astype(jnp.float32), fmt).astype(x.dtype))
        return dot_f32acc(xq, wq, dims).astype(x.dtype)
    return dot_f32acc(x, w, dims).astype(x.dtype)


def init_linear(key, d_in: int, d_out, scale: float | None = None,
                dtype=jnp.bfloat16) -> jax.Array:
    """Truncated-normal init, fan-in scaled. d_out may be a tuple."""
    shape = (d_in, *d_out) if isinstance(d_out, tuple) else (d_in, d_out)
    std = scale if scale is not None else d_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


class QLinear:
    """Namespace of helpers for (de)quantizing whole param trees at
    serve-packing time."""

    @staticmethod
    def pack_tree(params, predicate):
        """Replace every weight leaf selected by ``predicate(path)`` with its
        packed M2XFP representation. Paths are '/'-joined key tuples."""
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        treedef = jax.tree_util.tree_structure(params)
        out = []
        for path, leaf in flat:
            spath = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
            if predicate(spath, leaf):
                out.append(pack_serving_weight(leaf.astype(jnp.float32)))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)
