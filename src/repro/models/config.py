"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024              # GShard routing group

    # attention variants
    sliding_window: Optional[int] = None    # SWA window (mixtral, gemma2 local)
    local_global: bool = False              # gemma2: even layers local, odd global
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    block_kinds: Tuple[str, ...] = ()       # per-layer: attn|mlstm|slstm|mamba
    shared_attn_every: int = 0              # zamba2: shared attn after every N

    # modality frontend stub
    input_mode: str = "tokens"              # tokens | embeddings

    # sub-quadratic / bounded-cache decode => long_500k cell applies
    long_context_ok: bool = False

    norm_eps: float = 1e-5

    # quantization of GEMM operands (the paper's technique)
    quant: str = "none"                     # none | qat | serve
    quant_format: str = "m2xfp"             # m2xfp | mxfp4 | nvfp4
    kv_quant: str = "none"     # none | any codecs.kv_codecs() (Sec. 6.4)

    # distribution hints
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def kinds(self) -> Tuple[str, ...]:
        if self.block_kinds:
            return self.block_kinds
        return ("attn",) * self.n_layers

    @property
    def n_params(self) -> int:
        """Total parameter count (embeddings included once if tied)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.kinds:
            if kind == "attn":
                total += d * hd * (nh + 2 * nkv) + nh * hd * d  # qkv + o
                total += self._ffn_params()
                total += 2 * d                                   # norms
            elif kind == "mamba":
                total += self._mamba_params()
            elif kind == "mlstm":
                total += self._mlstm_params()
            elif kind == "slstm":
                total += self._slstm_params()
        if self.shared_attn_every:
            d_attn = self.hd * self.n_heads
            total += d * d_attn * 3 + d_attn * d + self._shared_ffn_params()
        return total

    def _ffn_params(self) -> int:
        d, ff = self.d_model, self.d_ff
        if self.is_moe:
            router = d * self.n_experts
            return router + self.n_experts * 3 * d * ff
        return 3 * d * ff  # SwiGLU: gate, up, down

    def _shared_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _mamba_params(self) -> int:
        d = self.d_model
        din = self.ssm_expand * d
        nheads = din // self.ssm_head_dim
        n = self.ssm_state
        # in_proj: z, x, B, C, dt ; conv ; A, D, dt_bias ; out_proj
        in_proj = d * (2 * din + 2 * n + nheads)
        conv = self.ssm_conv * (din + 2 * n)
        extras = 3 * nheads
        out_proj = din * d
        return in_proj + conv + extras + out_proj + 2 * d

    def _mlstm_params(self) -> int:
        d = self.d_model
        din = 2 * d
        h = self.n_heads
        return d * 2 * din + 4 * din + din * din // h * 3 + 3 * din + din * d + 2 * d

    def _slstm_params(self) -> int:
        d = self.d_model
        ff = int(d * 4 / 3)
        return 4 * d * d + 4 * d * d // self.n_heads + 4 * d + 2 * d * ff + 2 * d

    @property
    def active_params(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.n_params
        d, ff = self.d_model, self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * ff
        return self.n_params - inactive * sum(
            1 for k in self.kinds if k == "attn")
