"""Mixture-of-Experts FFN with GShard-style grouped top-k dispatch.

Tokens are routed in groups of ``moe_group_size``; each group's dispatch is
an einsum against a (G, E, C) one-hot — the GSPMD-native formulation, so
expert parallelism (experts sharded over the 'expert'/data axis) and expert
tensor parallelism (d_ff over 'model') both fall out of sharding
annotations. Capacity C = G * topk / E * capacity_factor (token dropping on
overflow, standard for the assigned MoE configs).

Router runs in f32 (standard practice; the paper quantizes GEMMs only).
Expert FFN GEMMs go through the same M2XFP quantization modes as dense
linears: qat fake-quants each expert's weights along the contraction dim,
serve keeps them packed at 4.5 bits/element.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .numerics import einsum_f32acc
from .quant import (
    PackedWeight, decode_serving_weight, fake_quant_act, fake_quant_weight,
    init_linear, ste,
)


def init_moe(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": init_linear(ks[0], d, e, dtype=jnp.float32),
        # expert weights stored contraction-dim first: (E, D, F) / (E, F, D)
        "gate": init_linear(ks[1], d, (e, ff), dtype=dtype).transpose(1, 0, 2),
        "up": init_linear(ks[2], d, (e, ff), dtype=dtype).transpose(1, 0, 2),
        "down": init_linear(ks[3], ff, (e, d), dtype=dtype).transpose(1, 0, 2),
    }


def _capacity(group: int, topk: int, n_experts: int, factor: float) -> int:
    c = int(group * topk / n_experts * factor)
    return max(8, (c + 3) // 4 * 4)


def _expert_matmul(xe: jax.Array, w, quant: str, fmt: str) -> jax.Array:
    """(ng, E, C, K) x per-expert weights (E, K, F) -> (ng, E, C, F).

    serve: w is a PackedWeight of the (K, E, N) transposed layout."""
    if quant == "serve" and isinstance(w, PackedWeight):
        wd = decode_serving_weight(w)                  # (K, E, N)
        xq = fake_quant_act(
            xe.astype(jnp.float32), w.codec).astype(wd.dtype)
        return einsum_f32acc("geck,kef->gecf", xq, wd).astype(xe.dtype)
    if quant == "qat":
        wq = ste(w, jax.vmap(lambda we: fake_quant_weight(
            we.astype(jnp.float32), fmt))(w).astype(w.dtype))
        xq = ste(xe, fake_quant_act(xe.astype(jnp.float32), fmt).astype(xe.dtype))
        return einsum_f32acc("geck,ekf->gecf", xq, wq).astype(xe.dtype)
    return einsum_f32acc("geck,ekf->gecf", xe, w).astype(xe.dtype)


def moe_apply(p: dict, x: jax.Array, cfg, quant: str = "none") -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, topk = cfg.n_experts, cfg.experts_per_token
    g = min(cfg.moe_group_size, b * s)
    ng = (b * s) // g
    cap = _capacity(g, topk, e, cfg.moe_capacity_factor)

    xt = x.reshape(ng, g, d)
    logits = jnp.einsum("ngd,de->nge", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (ng, g, E)
    top_p, top_i = jax.lax.top_k(probs, topk)                  # (ng, g, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalize

    # per-(token, slot) expert one-hot; position within expert counted
    # slot-major (slot 0 of all tokens first) — GShard priority order
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)       # (ng, g, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(ng, topk * g, e)
    pos_f = (jnp.cumsum(flat, axis=1) - flat) * flat
    pos = pos_f.reshape(ng, topk, g, e).transpose(0, 2, 1, 3)  # (ng, g, k, E)
    keep = (pos < cap).astype(jnp.float32) * onehot
    pos_i = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    # accumulate dispatch/combine per slot to bound the one-hot transient
    dispatch = jnp.zeros((ng, g, e, cap), jnp.bfloat16)
    combine = jnp.zeros((ng, g, e, cap), jnp.float32)
    for kslot in range(topk):
        oh = jax.nn.one_hot(pos_i[:, :, kslot], cap, dtype=jnp.float32)
        oh = oh * keep[:, :, kslot, :, None]                   # (ng, g, E, C)
        dispatch = dispatch + oh.astype(jnp.bfloat16)
        combine = combine + oh * top_p[:, :, kslot, None, None]
    dispatch = constrain(dispatch, ("batch", None, "expert", None))

    xe = einsum_f32acc("ngec,ngd->necd", dispatch,
                       xt.astype(jnp.bfloat16)).astype(x.dtype)
    xe = constrain(xe, ("batch", "expert", None, "embed"))
    h_g = _expert_matmul(xe, p["gate"], quant, cfg.quant_format)
    h_u = _expert_matmul(xe, p["up"], quant, cfg.quant_format)
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    h = constrain(h, ("batch", "expert", None, "expert_mlp"))
    ye = _expert_matmul(h, p["down"], quant, cfg.quant_format)  # (ng,E,C,D)
    y = einsum_f32acc("ngec,necd->ngd", combine.astype(x.dtype),
                      ye).astype(x.dtype)
    # output annotation: lets GSPMD lower the cross-expert reduction as a
    # reduce-scatter onto the token sharding instead of an all-reduce
    y = constrain(y, ("batch", None, "embed"))
    return y.reshape(b, s, d)
