"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode (zamba2's backbone; `long_500k` capable).

Faithful to the Mamba2 structure: fused in_proj -> (z, x, B, C, dt), causal
depthwise conv + SiLU on (x, B, C), per-head scalar decay a = exp(dt * A),
state h_t = a_t h_{t-1} + dt_t * B_t (x) x_t, output y_t = C_t . h_t + D x_t,
gated RMSNorm, out_proj. ngroups = 1 (B/C shared across heads).

Chunked SSD (chunk L): intra-chunk is an attention-like masked product
(C_t.B_s * exp(l_t - l_s)); inter-chunk carries (B, H, P, N) states through a
`lax.scan` over chunks — O(S L) + O(S/L) sequential steps instead of O(S).
All recurrence math in f32; GEMM-shaped contractions in bf16 -> f32.

The in/out projections go through ``quantized_matmul`` (the paper's
technique applies to the GEMM operands; the recurrence itself is not a GEMM
operand and stays full precision — see DESIGN.md Sec. 4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import rms_norm
from .quant import init_linear, quantized_matmul

CHUNK = 128


def _dims(cfg):
    din = cfg.ssm_expand * cfg.d_model
    nheads = din // cfg.ssm_head_dim
    return din, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    din, h, p_, n = _dims(cfg)
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], d, 2 * din + 2 * n + h, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((din,), jnp.float32),
        "out_proj": init_linear(ks[3], din, d, dtype=dtype),
    }


def _split_proj(zxbcdt, cfg):
    din, h, p_, n = _dims(cfg)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * n]
    dt = zxbcdt[..., din + din + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(out)


def mamba2_forward(p: dict, x: jax.Array, cfg, quant: str = "none"):
    """Full-sequence SSD. x: (B, S, D). Returns (y, final_state)."""
    bsz, s, d = x.shape
    din, h, hp, n = _dims(cfg)
    l = min(CHUNK, s)
    nc = s // l

    zxbcdt = quantized_matmul(x, p["in_proj"], quant, cfg.quant_format)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :din].reshape(bsz, s, h, hp)             # (B,S,H,P) f32
    bmat = xbc[..., din:din + n]                            # (B,S,N)
    cmat = xbc[..., din + n:]                               # (B,S,N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                                # (H,)
    loga = dt * a                                           # log decay <= 0

    # chunk views
    xs_c = (xs * dt[..., None]).reshape(bsz, nc, l, h, hp)  # dt-weighted input
    b_c = bmat.reshape(bsz, nc, l, n)
    c_c = cmat.reshape(bsz, nc, l, n)
    la_c = loga.reshape(bsz, nc, l, h)
    lcum = jnp.cumsum(la_c, axis=2)                         # (B,nc,L,H)

    # ---- intra-chunk (attention-like, causal) ----
    cb = jnp.einsum("bcin,bcjn->bcij", c_c, b_c)            # (B,nc,L,L)
    ldiff = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    # mask INSIDE the exponent: exp of masked (+large) entries would be inf
    # and poison the backward pass with inf * 0 cotangents
    ldiff = jnp.where(mask[None, None, :, :, None], ldiff, -1e9)
    decay = jnp.exp(ldiff)
    scores = cb[..., None] * decay                          # (B,nc,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xs_c)

    # ---- chunk states + inter-chunk scan ----
    decay_to_end = jnp.exp(lcum[:, :, -1:, :] - lcum)       # (B,nc,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        b_c, decay_to_end, xs_c)            # (B,nc,H,P,N)
    chunk_decay = jnp.exp(lcum[:, :, -1, :])                # (B,nc,H)

    def step(carry, inp):
        st, cd = inp                                        # (B,H,P,N),(B,H)
        out = carry
        new = carry * cd[:, :, None, None] + st
        return new, out

    init = jnp.zeros((bsz, h, hp, n), jnp.float32)
    final, h_prev = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcln,bchpn->bclhp", c_c, h_prev) \
        * jnp.exp(lcum)[..., None]                          # decay from start
    y = (y_intra + y_inter).reshape(bsz, s, h, hp) \
        + xs * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"],
                 cfg.norm_eps)
    out = quantized_matmul(y.astype(x.dtype), p["out_proj"], quant,
                           cfg.quant_format)
    return out, {"ssm": final, "conv": xbc_raw_tail(zxbcdt, cfg, s)}


def xbc_raw_tail(zxbcdt: jax.Array, cfg, s: int) -> jax.Array:
    """Last (conv-1) pre-conv inputs — the decode conv state."""
    din, h, p_, n = _dims(cfg)
    xbc = zxbcdt[..., din:din + din + 2 * n]
    k = cfg.ssm_conv
    return xbc[:, s - (k - 1):, :].astype(jnp.float32)


def init_mamba2_cache(cfg, batch: int) -> dict:
    din, h, p_, n = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p_, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * n), jnp.float32),
    }


def mamba2_decode(p: dict, x: jax.Array, cfg, cache: dict,
                  quant: str = "none"):
    """Single-token step. x: (B, 1, D). Returns (y, new_cache)."""
    bsz = x.shape[0]
    din, h, hp, n = _dims(cfg)
    zxbcdt = quantized_matmul(x, p["in_proj"], quant, cfg.quant_format)
    z, xbc_new, dt = _split_proj(zxbcdt[:, 0], cfg)          # (B, ...)

    # conv state: append new, convolve the window of size K
    win = jnp.concatenate(
        [cache["conv"], xbc_new.astype(jnp.float32)[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xs = xbc[:, :din].reshape(bsz, h, hp)
    bvec = xbc[:, din:din + n]
    cvec = xbc[:, din + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                   # (B,H)
    hnew = cache["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs, bvec)
    y = jnp.einsum("bn,bhpn->bhp", cvec, hnew) + xs * p["D"][None, :, None]
    y = y.reshape(bsz, 1, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32))[:, None, :],
                 p["norm"], cfg.norm_eps)
    out = quantized_matmul(y.astype(x.dtype), p["out_proj"], quant,
                           cfg.quant_format)
    return out, {"ssm": hnew, "conv": win[:, 1:, :]}
