"""Unified decoder LM over per-layer block patterns.

Families:
  * dense / moe / audio / vlm : homogeneous attention blocks (GQA + MLP or
    MoE), lax.scan over stacked layer params (O(1) compile in depth).
  * ssm (xlstm)   : alternating mLSTM / sLSTM blocks, scanned in pairs.
  * hybrid (zamba2): Mamba2 backbone with a *shared* attention block applied
    after every ``shared_attn_every`` Mamba layers (single weight set).

Three entry points, all pure functions over a params pytree:
  forward(...)      -> logits (+ caches)    train / prefill
  decode_step(...)  -> logits, new caches   single-token serving
  loss_fn(...)      -> scalar LM loss       next-token cross-entropy

Quantization mode (cfg.quant): 'none' | 'qat' | 'serve' — threaded to every
GEMM. ``pack_params_for_serving`` converts dense trained params into packed
M2XFP streams (4.5 bits/elem resident) for the serve path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import attention as attn
from . import mamba2 as mb
from . import xlstm as xl
from .layers import init_embedding, init_mlp, init_rms_norm, mlp_apply, \
    rms_norm, softcap
from .moe import init_moe, moe_apply
from .quant import pack_serving_weight

__all__ = [
    "init_params", "forward", "decode_step", "prefill_chunk", "loss_fn",
    "init_caches", "pack_params_for_serving", "layer_windows",
]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg, dtype=jnp.bfloat16) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ffn_norm": init_rms_norm(cfg.d_model),
    }
    p["ffn"] = init_moe(k2, cfg, dtype) if cfg.is_moe else \
        init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack(keys, init_fn):
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(
            keys[1], cfg.vocab_size, cfg.d_model, dtype).T

    kinds = cfg.kinds
    if cfg.family == "ssm":                                  # xlstm pairs
        n_pairs = cfg.n_layers // 2
        params["mlstm"] = _stack(
            jax.random.split(keys[2], n_pairs),
            lambda k: xl.init_mlstm(k, cfg, dtype))
        params["mlstm_norm"] = jnp.ones((n_pairs, cfg.d_model), jnp.float32)
        params["slstm"] = _stack(
            jax.random.split(keys[3], n_pairs),
            lambda k: xl.init_slstm(k, cfg, dtype))
        params["slstm_norm"] = jnp.ones((n_pairs, cfg.d_model), jnp.float32)
    elif cfg.family == "hybrid":                             # zamba2
        n_mamba = sum(1 for k in kinds if k == "mamba")
        params["mamba"] = _stack(
            jax.random.split(keys[2], n_mamba),
            lambda k: mb.init_mamba2(k, cfg, dtype))
        params["mamba_norm"] = jnp.ones((n_mamba, cfg.d_model), jnp.float32)
        params["shared_attn"] = _init_attn_block(keys[3], cfg, dtype)
    else:                                                    # attention LMs
        params["layers"] = _stack(
            jax.random.split(keys[2], cfg.n_layers),
            lambda k: _init_attn_block(k, cfg, dtype))
    return params


def layer_windows(cfg) -> jax.Array:
    """Per-attention-layer window size (0 = global). gemma2: even layers
    local; mixtral: all layers SWA; else global."""
    n = cfg.n_layers
    if cfg.local_global:
        w = jnp.where(jnp.arange(n) % 2 == 0, cfg.sliding_window or 4096, 0)
    elif cfg.sliding_window:
        w = jnp.full((n,), cfg.sliding_window)
    else:
        w = jnp.zeros((n,), jnp.int32)
    return w.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_block_forward(p, h, cfg, positions, window, quant):
    """window: traced int32 scalar, 0 = global."""
    x = rms_norm(h, p["attn_norm"], cfg.norm_eps)
    # masks accept a traced window: encode 'global' as a huge window
    eff_w = jnp.where(window > 0, window, jnp.int32(2 ** 30))
    out, kv = attn.attention_forward(
        p["attn"], x, cfg, positions, window=eff_w, quant=quant)
    h = h + out
    x = rms_norm(h, p["ffn_norm"], cfg.norm_eps)
    ffn = moe_apply(p["ffn"], x, cfg, quant) if cfg.is_moe else \
        mlp_apply(p["ffn"], x, quant, cfg.quant_format)
    h = constrain(h + ffn, ("batch", "seq_sp", "embed"))
    return h, kv


def _attn_block_decode(p, h, cfg, cache, index, window, quant):
    x = rms_norm(h, p["attn_norm"], cfg.norm_eps)
    eff_w = jnp.where(window > 0, window, jnp.int32(2 ** 30))
    out, new_cache = attn.attention_decode(
        p["attn"], x, cfg, cache, index, window=eff_w, quant=quant)
    h = h + out
    x = rms_norm(h, p["ffn_norm"], cfg.norm_eps)
    ffn = moe_apply(p["ffn"], x, cfg, quant) if cfg.is_moe else \
        mlp_apply(p["ffn"], x, quant, cfg.quant_format)
    return h + ffn, new_cache


def _attn_block_prefill(p, h, cfg, cache, index, lengths, window, quant):
    """Chunked-prefill twin of ``_attn_block_decode``: h is (B, T, d)."""
    x = rms_norm(h, p["attn_norm"], cfg.norm_eps)
    eff_w = jnp.where(window > 0, window, jnp.int32(2 ** 30))
    out, new_cache = attn.attention_prefill(
        p["attn"], x, cfg, cache, index, lengths, window=eff_w, quant=quant)
    h = h + out
    x = rms_norm(h, p["ffn_norm"], cfg.norm_eps)
    ffn = moe_apply(p["ffn"], x, cfg, quant) if cfg.is_moe else \
        mlp_apply(p["ffn"], x, quant, cfg.quant_format)
    return h + ffn, new_cache


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, batch):
    if cfg.input_mode == "embeddings":
        h = batch["embeds"]
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    return constrain(h, ("batch", "seq", "embed"))


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    from .numerics import dot_f32acc
    logits = dot_f32acc(h, head, (((h.ndim - 1,), (0,)), ((), ())))
    logits = softcap(logits, cfg.final_softcap)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if not cfg.remat:
        return fn
    from repro.core import envflags
    pol = envflags.get_str("REPRO_REMAT_POLICY")
    policy = {
        "none": None,                       # save only block inputs
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch":
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[pol]
    return jax.checkpoint(fn, policy=policy)


def forward(params: dict, cfg, batch: dict, collect_cache: bool = False):
    """batch: {"tokens": (B,S)} or {"embeds": (B,S,D)}; optional "positions".

    Returns logits (B,S,V); with ``collect_cache`` also per-layer prefill
    K/V stacks (for attention families)."""
    h = _embed_in(params, cfg, batch)
    b, s = h.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    quant = cfg.quant

    if cfg.family == "ssm":
        def pair_body(h, xs):
            pm, pnm, ps, pns = xs
            x = rms_norm(h, pnm, cfg.norm_eps)
            out, _ = xl.mlstm_forward(pm, x, cfg, quant)
            h = h + out
            x = rms_norm(h, pns, cfg.norm_eps)
            out, _ = xl.slstm_forward(ps, x, cfg, quant)
            return h + out, None

        h, _ = jax.lax.scan(
            _remat(pair_body, cfg), h,
            (params["mlstm"], params["mlstm_norm"],
             params["slstm"], params["slstm_norm"]))
        return _logits(params, cfg, h)

    if cfg.family == "hybrid":
        h = _hybrid_forward(params, cfg, h, positions, quant)
        return _logits(params, cfg, h)

    windows = layer_windows(cfg)

    def body(h, xs):
        lp, w = xs
        hn, kv = _attn_block_forward(lp, h, cfg, positions, w, quant)
        return hn, kv if collect_cache else None

    h, kvs = jax.lax.scan(_remat(body, cfg), h, (params["layers"], windows))
    logits = _logits(params, cfg, h)
    if collect_cache:
        return logits, kvs
    return logits


def _hybrid_segments(cfg):
    """zamba2 layout: every ``shared_attn_every``-th position is the shared
    attention block. Returns (n_segments, seg_len, n_trailing_mamba)."""
    every = cfg.shared_attn_every
    n_attn = cfg.n_layers // every
    seg = every - 1
    n_mamba = cfg.n_layers - n_attn
    trailing = n_mamba - n_attn * seg
    return n_attn, seg, trailing


def _hybrid_forward(params, cfg, h, positions, quant):
    n_seg, seg, trailing = _hybrid_segments(cfg)

    def mamba_body(h, xs):
        pm, pn = xs
        x = rms_norm(h, pn, cfg.norm_eps)
        out, _ = mb.mamba2_forward(pm, x, cfg, quant)
        return h + out, None

    mparams = (params["mamba"], params["mamba_norm"])
    head_p = jax.tree.map(
        lambda a: a[:n_seg * seg].reshape(n_seg, seg, *a.shape[1:]), mparams)
    sa = params["shared_attn"]

    def seg_body(h, xs):
        h, _ = jax.lax.scan(_remat(mamba_body, cfg), h, xs)
        h, _ = _remat(
            lambda hh: _attn_block_forward(
                sa, hh, cfg, positions, jnp.int32(0), quant), cfg)(h)
        return h, None

    h, _ = jax.lax.scan(seg_body, h, head_p)
    tail_p = jax.tree.map(lambda a: a[n_seg * seg:], mparams)
    if trailing:
        h, _ = jax.lax.scan(_remat(mamba_body, cfg), h, tail_p)
    return h


# ---------------------------------------------------------------------------
# Decode (single token against caches)
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                per_slot: bool = False) -> dict:
    """Cache pytree for decode_step.

    ``per_slot=True``: paged serving layout — attention positions are
    tracked per batch row so each row is an independent request slot
    (continuous batching; see repro.serve.engine). decode_step must then
    receive a (B,) index vector instead of a scalar."""
    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        return {
            "mlstm": jax.vmap(lambda _: xl.init_mlstm_cache(cfg, batch))(
                jnp.arange(n_pairs)),
            "slstm": jax.vmap(lambda _: xl.init_slstm_cache(cfg, batch))(
                jnp.arange(n_pairs)),
        }
    if cfg.family == "hybrid":
        n_seg, seg, trailing = _hybrid_segments(cfg)
        n_mamba = n_seg * seg + trailing
        return {
            "mamba": jax.vmap(lambda _: mb.init_mamba2_cache(cfg, batch))(
                jnp.arange(n_mamba)),
            "attn": jax.vmap(
                lambda _: attn.init_cache(cfg, batch, max_len, dtype=dtype,
                                          per_slot=per_slot))(
                jnp.arange(n_seg)),
        }
    if cfg.local_global:
        # gemma2 pattern: (local, global) pairs — order-preserving scan unit
        n_pairs = cfg.n_layers // 2
        w = cfg.sliding_window or 4096
        local = jax.vmap(
            lambda _: attn.init_cache(cfg, batch, max_len, window=w,
                                      dtype=dtype, per_slot=per_slot))(
            jnp.arange(n_pairs))
        glob = jax.vmap(
            lambda _: attn.init_cache(cfg, batch, max_len, dtype=dtype,
                                      per_slot=per_slot))(jnp.arange(n_pairs))
        return {"local": local, "global": glob}
    w = cfg.sliding_window
    layers = jax.vmap(
        lambda _: attn.init_cache(cfg, batch, max_len, window=w,
                                  dtype=dtype, per_slot=per_slot))(
        jnp.arange(cfg.n_layers))
    return {"layers": layers}


def decode_step(params: dict, cfg, batch: dict, caches: dict,
                index: jax.Array):
    """One token for the whole batch. batch: {"tokens": (B,1)} or embeds.
    ``index``: absolute position — scalar int32 (all rows in lockstep) or a
    (B,) int32 vector with per-slot caches (continuous batching; the serve
    engine's path). Returns (logits, caches)."""
    h = _embed_in(params, cfg, batch)
    quant = cfg.quant

    if cfg.family == "ssm":
        def pair_body(h, xs):
            pm, pnm, ps, pns, cm, cs = xs
            x = rms_norm(h, pnm, cfg.norm_eps)
            out, cm = xl.mlstm_decode(pm, x, cfg, cm, quant)
            h = h + out
            x = rms_norm(h, pns, cfg.norm_eps)
            out, cs = xl.slstm_decode(ps, x, cfg, cs, quant)
            return h + out, (cm, cs)

        h, (cm, cs) = jax.lax.scan(
            pair_body, h,
            (params["mlstm"], params["mlstm_norm"], params["slstm"],
             params["slstm_norm"], caches["mlstm"], caches["slstm"]))
        return _logits(params, cfg, h), {"mlstm": cm, "slstm": cs}

    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, h, caches, index, quant)

    windows = layer_windows(cfg)
    if cfg.local_global:
        n_pairs = cfg.n_layers // 2
        pair_params = jax.tree.map(
            lambda a: a.reshape(n_pairs, 2, *a.shape[1:]), params["layers"])
        w_local = jnp.int32(cfg.sliding_window or 4096)

        def pair_body(h, xs):
            lp, cl, cg = xs
            p_loc = jax.tree.map(lambda a: a[0], lp)
            p_glo = jax.tree.map(lambda a: a[1], lp)
            h, cl = _attn_block_decode(p_loc, h, cfg, cl, index, w_local, quant)
            h, cg = _attn_block_decode(p_glo, h, cfg, cg, index,
                                       jnp.int32(0), quant)
            return h, (cl, cg)

        h, (cl, cg) = jax.lax.scan(
            pair_body, h, (pair_params, caches["local"], caches["global"]))
        return _logits(params, cfg, h), {"local": cl, "global": cg}

    def body(h, xs):
        lp, w, c = xs
        hn, nc = _attn_block_decode(lp, h, cfg, c, index, w, quant)
        return hn, nc

    h, nc = jax.lax.scan(body, h, (params["layers"], windows,
                                   caches["layers"]))
    return _logits(params, cfg, h), {"layers": nc}


def prefill_chunk(params: dict, cfg, batch: dict, caches: dict,
                  index: jax.Array, lengths: jax.Array):
    """Chunked prefill for the serving engine: up to T prompt tokens per
    slot in ONE launch through the same fused dequant-GEMM path as
    ``decode_step``.

    batch: {"tokens": (B, T)}; ``index`` (B,): absolute position of column
    0 per slot; ``lengths`` (B,): valid tokens per row, 0..T (0 = idle row,
    its caches are untouched). Requires per-slot caches
    (``init_caches(..., per_slot=True)``).

    Returns (logits (B, T, V), caches). ``logits[b, t]`` for t <
    ``lengths[b]`` is bit-identical to what ``decode_step`` would emit
    feeding the same tokens one at a time (the serve parity tests pin
    this); positions at or past ``lengths[b]`` are garbage to discard.

    Attention families only — ssm/hybrid recurrent state is inherently
    sequential per token, so the serve engine falls back to one-token
    teacher forcing there."""
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"chunked prefill needs attention caches; family "
            f"{cfg.family!r} decodes one token at a time")
    h = _embed_in(params, cfg, batch)
    quant = cfg.quant

    windows = layer_windows(cfg)
    if cfg.local_global:
        n_pairs = cfg.n_layers // 2
        pair_params = jax.tree.map(
            lambda a: a.reshape(n_pairs, 2, *a.shape[1:]), params["layers"])
        w_local = jnp.int32(cfg.sliding_window or 4096)

        def pair_body(h, xs):
            lp, cl, cg = xs
            p_loc = jax.tree.map(lambda a: a[0], lp)
            p_glo = jax.tree.map(lambda a: a[1], lp)
            h, cl = _attn_block_prefill(p_loc, h, cfg, cl, index, lengths,
                                        w_local, quant)
            h, cg = _attn_block_prefill(p_glo, h, cfg, cg, index, lengths,
                                        jnp.int32(0), quant)
            return h, (cl, cg)

        h, (cl, cg) = jax.lax.scan(
            pair_body, h, (pair_params, caches["local"], caches["global"]))
        return _logits(params, cfg, h), {"local": cl, "global": cg}

    def body(h, xs):
        lp, w, c = xs
        hn, nc = _attn_block_prefill(lp, h, cfg, c, index, lengths, w, quant)
        return hn, nc

    h, nc = jax.lax.scan(body, h, (params["layers"], windows,
                                   caches["layers"]))
    return _logits(params, cfg, h), {"layers": nc}


def _hybrid_decode(params, cfg, h, caches, index, quant):
    n_seg, seg, trailing = _hybrid_segments(cfg)

    def mamba_body(h, xs):
        pm, pn, c = xs
        x = rms_norm(h, pn, cfg.norm_eps)
        out, c = mb.mamba2_decode(pm, x, cfg, c, quant)
        return h + out, c

    mparams = (params["mamba"], params["mamba_norm"])
    head_p = jax.tree.map(
        lambda a: a[:n_seg * seg].reshape(n_seg, seg, *a.shape[1:]), mparams)
    head_c = jax.tree.map(
        lambda a: a[:n_seg * seg].reshape(n_seg, seg, *a.shape[1:]),
        caches["mamba"])
    sa = params["shared_attn"]

    def seg_body(h, xs):
        (pp, nn), mc, ac = xs
        h, mc_new = jax.lax.scan(mamba_body, h, (pp, nn, mc))
        h, ac_new = _attn_block_decode(sa, h, cfg, ac, index,
                                       jnp.int32(0), quant)
        return h, (mc_new, ac_new)

    h, (mc_head, ac_new) = jax.lax.scan(
        seg_body, h, ((head_p[0], head_p[1]), head_c, caches["attn"]))
    tail_p = jax.tree.map(lambda a: a[n_seg * seg:], mparams)
    tail_c = jax.tree.map(lambda a: a[n_seg * seg:], caches["mamba"])
    if trailing:
        h, mc_tail = jax.lax.scan(mamba_body, h, (*tail_p, tail_c))
        mc_new = jax.tree.map(
            lambda hd, tl: jnp.concatenate(
                [hd.reshape(-1, *hd.shape[2:]), tl], axis=0),
            mc_head, mc_tail)
    else:
        mc_new = jax.tree.map(lambda hd: hd.reshape(-1, *hd.shape[2:]), mc_head)
    logits = _logits(params, cfg, h)
    return logits, {"mamba": mc_new, "attn": ac_new}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(params: dict, cfg, batch: dict) -> jax.Array:
    """Next-token cross-entropy (labels = batch['labels'], negatives ignored).

    Written as logsumexp - masked-reduce (no take_along_axis): the gather
    form would force GSPMD to all-gather the vocab-sharded logits; the
    masked reduce contracts the sharded axis locally + one small
    all-reduce, and XLA fuses the one-hot select into the reduction."""
    logits = forward(params, cfg, batch)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    picked = jnp.sum(
        jnp.where(safe[..., None] == vocab_iota, lf, 0.0), axis=-1)
    nll = lse - picked
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)


# ---------------------------------------------------------------------------
# Serving: pack every GEMM weight into M2XFP streams
# ---------------------------------------------------------------------------

_PACK_KEYS = ("wq", "wk", "wv", "wo", "gate", "up", "down", "in_proj",
              "out_proj", "w", "ff_up", "ff_down", "w_o")
_SKIP_KEYS = ("router", "conv_w", "conv_b", "A_log", "D", "dt_bias", "norm",
              "b_if", "w_if", "r", "b", "gn", "embed", "lm_head")


def pack_params_for_serving(params: dict, cfg) -> dict:
    """Convert dense params -> packed streams of ``cfg.quant_format`` for
    every GEMM weight (m2xfp: 4.5 bits/elem Sg-EM). Stacked (per-layer)
    weights are packed with vmap. Embedding / router / recurrence params
    stay bf16 (not GEMM operands in the paper's scope). Raises if the
    configured codec has no packed serving path."""
    from repro.core.codecs import get_codec, packed_codecs
    fmt = cfg.quant_format
    if not get_codec(fmt).packed:
        raise ValueError(
            f"cfg.quant_format={fmt!r} has no packed serving path; "
            f"packable codecs: {', '.join(packed_codecs())}")

    def convert(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        stacked = any(k in ("layers", "mlstm", "slstm", "mamba") for k in keys)
        if "mlstm" in keys and name in ("wq", "wk", "wv"):
            return leaf        # per-head block-diagonal cell projections
        if name in _PACK_KEYS and leaf.ndim >= 2 and name not in _SKIP_KEYS:
            w = leaf.astype(jnp.float32)
            if name in ("gate", "up", "down") and w.ndim - (1 if stacked else 0) == 3:
                # MoE expert weights (.., E, K, N) -> contraction-first (K,E,N)
                perm = (list(range(w.ndim - 3)) +
                        [w.ndim - 2, w.ndim - 3, w.ndim - 1])
                w = w.transpose(perm)
            if w.shape[-2] % 32 != 0:
                return leaf                                   # non-groupable
            if stacked:
                return jax.vmap(lambda wi: pack_serving_weight(wi, fmt))(w)
            return pack_serving_weight(w, fmt)
        return leaf

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    leaves = [convert(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)
