"""GQA attention supporting every assigned variant:

  * grouped KV heads (any n_kv <= n_heads), KV-head repeat under TP
  * sliding-window attention (mixtral; gemma2 local layers)
  * local/global alternating layers (gemma2)
  * attention logit soft-capping (gemma2)
  * qk-norm (qwen3), QKV bias (qwen2/2.5)
  * ring-buffer KV cache for bounded-window decode; sequence-sharded cache
    for 32k/500k decode (softmax reduction crosses the shard axis — the
    GSPMD equivalent of ring attention)

Train/prefill attention is **chunked flash-style**: a lax.scan over KV
chunks carrying the running (max, normalizer, accumulator) — activation
memory is O(S * chunk) instead of O(S^2), which is what makes prefill_32k
lowerable at all. KV heads are repeated to n_heads *per chunk* so every
attention tensor shards uniformly on the head axis (GSPMD pads 40 -> 48
heads over 16-way TP; the KV *cache* keeps n_kv heads — the GQA memory win
is preserved).

Quantized GEMMs (the paper's technique) apply to the QKV/O projections via
``quantized_matmul``; the KV cache itself can additionally be stored in
M2XFP (Sg-EM for K/V per paper Sec. 6.4) — see kvquant.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import envflags
from repro.distributed.sharding import constrain
from .layers import apply_rope, rms_norm, softcap
from .numerics import einsum_f32acc
from .quant import init_linear, quantized_matmul

NEG_INF = -2.0e38


# Positive-int env override with hard validation — a zero or negative
# chunk/tile would silently produce broken tiling far from the setting.
# Kept under its historical name; the parsing lives in repro.core.envflags.
from repro.core.envflags import env_int as _env_int  # noqa: E402

# perf levers (§Perf): larger chunks -> fewer scan iterations -> less
# carry/operand re-traffic; smaller -> lower live memory
KV_CHUNK = envflags.get_int("REPRO_ATTN_KV_CHUNK")
Q_TILE = envflags.get_int("REPRO_ATTN_Q_TILE")


def init_attention(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, nh * hd, dtype=dtype),
        "wk": init_linear(ks[1], d, nkv * hd, dtype=dtype),
        "wv": init_linear(ks[2], d, nkv * hd, dtype=dtype),
        "wo": init_linear(ks[3], nh * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg, positions, quant):
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = quantized_matmul(x, p["wq"], quant, cfg.quant_format)
    k = quantized_matmul(x, p["wk"], quant, cfg.quant_format)
    v = quantized_matmul(x, p["wv"], quant, cfg.quant_format)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, T, nkv, hd) -> (B, T, nkv*n_rep, hd)."""
    if n_rep == 1:
        return x
    b, t, nkv, hd = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, t, nkv, n_rep, hd)
    ).reshape(b, t, nkv * n_rep, hd)


def _pad_chunks(x, pos, chunk):
    """Pad KV seq to a chunk multiple; padded positions get -1 (masked)."""
    t = x[0].shape[1]
    pad = (-t) % chunk
    if pad == 0:
        return x, pos
    x = [jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in x]
    pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    return x, pos


def _chunked_attention(q, k, v, pos_q, pos_k, cfg, window,
                       chunk: int = KV_CHUNK, q_tile: int = Q_TILE):
    """Flash-style streaming attention, q-tiled.

    Outer lax.scan over q tiles of ``q_tile`` (bounds the live score/acc
    buffers to O(B * nh * q_tile * chunk) instead of O(B * nh * S * chunk) —
    this is what keeps prefill_32k inside HBM); inner scan over KV chunks
    with running (max, normalizer, accumulator).

    q (B,S,nh,hd); k/v (B,T,nkv,hd); pos_* (B, S/T) absolute positions
    (-1 = invalid kv). ``window`` traced int32 (2^30 = global).
    Returns (B, S, nh, hd) f32."""
    b, s, nh, hd = q.shape
    if s > q_tile and s % q_tile == 0:
        nq = s // q_tile
        qt = q.reshape(b, nq, q_tile, nh, hd).transpose(1, 0, 2, 3, 4)
        pt = pos_q.reshape(b, nq, q_tile).transpose(1, 0, 2)

        def tile_body(_, xs):
            q_i, p_i = xs
            out = _chunked_attention_inner(q_i, k, v, p_i, pos_k, cfg,
                                           window, chunk)
            return None, out

        _, outs = jax.lax.scan(tile_body, None, (qt, pt))
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)
    return _chunked_attention_inner(q, k, v, pos_q, pos_k, cfg, window,
                                    chunk)


def _chunked_attention_inner(q, k, v, pos_q, pos_k, cfg, window,
                             chunk: int = KV_CHUNK):
    b, s, nh, hd = q.shape
    n_rep = nh // k.shape[2]
    c = min(chunk, k.shape[1])
    (k, v), pos_k = _pad_chunks([k, v], pos_k, c)
    t = k.shape[1]
    nc = t // c
    kc = k.reshape(b, nc, c, -1, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, c, -1, hd).transpose(1, 0, 2, 3, 4)
    pc = pos_k.reshape(b, nc, c).transpose(1, 0, 2)

    qf = q.astype(jnp.bfloat16)
    scale = hd ** -0.5

    def step(carry, xs):
        m, l, acc = carry
        kch, vch, pch = xs                       # (B,c,nkv,hd), (B,c)
        kch = _repeat_kv(kch, n_rep)
        vch = _repeat_kv(vch, n_rep)
        sc = einsum_f32acc("bsnd,bcnd->bnsc", qf,
                           kch.astype(jnp.bfloat16)) * scale
        sc = softcap(sc, cfg.attn_softcap)
        valid = (pch >= 0)[:, None, :] & \
            (pos_q[:, :, None] >= pch[:, None, :]) & \
            (pos_q[:, :, None] - pch[:, None, :] < window)  # (B,S,c)
        validb = valid[:, None, :, :]                        # (B,1,S,c)
        sc = jnp.where(validb, sc, NEG_INF)
        sc = constrain(sc, ("batch", "heads", None, None))
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.where(validb, jnp.exp(sc - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = einsum_f32acc("bnsc,bcnd->bnsd", p.astype(jnp.bfloat16),
                           vch.astype(jnp.bfloat16))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, nh, s), NEG_INF, jnp.float32),
            jnp.zeros((b, nh, s), jnp.float32),
            jnp.zeros((b, nh, s, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3)                         # (B,S,nh,hd)


def attention_forward(
    p: dict, x: jax.Array, cfg, positions: jax.Array,
    window=None, quant: str = "none",
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, x, cfg, positions, quant)
    w = jnp.int32(2 ** 30) if window is None else window
    out = _chunked_attention(q, k, v, positions, positions, cfg, w)
    b, s = x.shape[:2]
    out = out.reshape(b, s, -1).astype(x.dtype)
    out = constrain(out, ("batch", "seq", "q_dim"))
    out = quantized_matmul(out, p["wo"], quant, cfg.quant_format)
    return out, (k, v)


def _row_update(buf: jax.Array, new: jax.Array, start: jax.Array) -> jax.Array:
    """Write ``new`` into ``buf`` at offset ``start`` along the leading axis."""
    return jax.lax.dynamic_update_slice(
        buf, new.astype(buf.dtype), (start,) + (0,) * (buf.ndim - 1))


def _masked_rows(old: jax.Array, new: jax.Array, valid) -> jax.Array:
    """Per-row select: rows where ``valid`` take ``new``, others keep ``old``."""
    if valid is None:
        return new
    return jnp.where(
        valid.reshape((-1,) + (1,) * (old.ndim - 1)), new, old)


def _attend_one(q, k_new, v_new, out_dtype, cfg, cache, index, window,
                valid=None):
    """Write ONE token's K/V per row at ``index % W`` and attend ``q``
    against the whole cache — the shared inner step of ``attention_decode``
    (valid=None) and ``attention_prefill`` (``valid`` masks rows past the
    slot's chunk length; their cache rows stay untouched and their context
    output is garbage for the caller to discard).

    q (B,1,nh,hd); k_new/v_new (B,1,nkv,hd); ``index`` scalar int32 or (B,)
    (per-slot caches). Returns (ctx (B,1,nh*hd) in ``out_dtype`` — the
    pre-``wo`` attention context, new cache dict). The cache is
    sequence-sharded ('kv_seq' -> TP axis); the softmax reduction over W
    crosses shards (GSPMD ring-attention-equivalent)."""
    b = q.shape[0]
    quantized_kv = cfg.kv_quant != "none"
    w = (cache["k"]["codes"] if quantized_kv else cache["k"]).shape[1]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    per_slot = jnp.ndim(index) == 1
    if valid is not None and not per_slot:
        raise ValueError("masked cache writes need per-slot caches")
    if per_slot:
        pos_new = index.reshape(b, 1).astype(jnp.int32)
    else:
        pos_new = jnp.full((b, 1), index, dtype=jnp.int32)

    slot = jnp.mod(index, w)                       # scalar or (B,)
    if quantized_kv:
        from .kvquant import kv_decode, kv_encode, kv_page_write
        kc, vc = {}, {}
        for name, new, store in (("k", k_new, kc), ("v", v_new, vc)):
            enc = kv_encode(new, cfg.kv_quant)
            if per_slot:
                upd = kv_page_write(cache[name], enc, slot, valid)
            else:
                upd = {key: jax.lax.dynamic_update_slice(
                    cache[name][key], enc[key], (0, slot, 0, 0))
                    for key in enc}
            for key in upd:
                store[key] = constrain(
                    upd[key], ("batch", "kv_seq", "kv_heads", None))
        k = kv_decode(kc, cfg.kv_quant)
        v = kv_decode(vc, cfg.kv_quant)
    else:
        if per_slot:
            k = _masked_rows(
                cache["k"], jax.vmap(_row_update)(cache["k"], k_new, slot),
                valid)
            v = _masked_rows(
                cache["v"], jax.vmap(_row_update)(cache["v"], v_new, slot),
                valid)
        else:
            k = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        kc, vc = k, v
    if per_slot:
        pos = _masked_rows(
            cache["pos"], jax.vmap(_row_update)(cache["pos"], pos_new, slot),
            valid)
    else:
        pos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((1,), index, jnp.int32), (slot,))
    k = constrain(k, ("batch", "kv_seq", "kv_heads", None))
    v = constrain(v, ("batch", "kv_seq", "kv_heads", None))

    eff_w = jnp.int32(2 ** 30) if window is None else window
    # single-token scores over the whole cache: (B, nkv, g, W)
    g = nh // nkv
    qh = q.reshape(b, nkv, g, hd).astype(jnp.bfloat16)
    sc = einsum_f32acc("bkgd,bwkd->bkgw", qh,
                       k.astype(jnp.bfloat16)) * (hd ** -0.5)
    sc = softcap(sc, cfg.attn_softcap)
    pos2d = pos if per_slot else pos[None, :]      # (B, W) or (1, W)
    idx2d = index[:, None] if per_slot else index
    valid_kv = (pos2d >= 0) & (pos2d <= idx2d) & (idx2d - pos2d < eff_w)
    sc = jnp.where(valid_kv[:, None, None, :], sc, NEG_INF)
    sc = constrain(sc, ("batch", "kv_heads", None, "kv_seq"))
    probs = jax.nn.softmax(sc, axis=-1)
    out = einsum_f32acc("bkgw,bwkd->bkgd", probs.astype(jnp.bfloat16),
                        v.astype(jnp.bfloat16))
    ctx = out.reshape(b, 1, nh * hd).astype(out_dtype)
    return ctx, {"k": kc, "v": vc, "pos": pos}


def attention_decode(
    p: dict, x: jax.Array, cfg, cache: dict, index: jax.Array,
    window=None, quant: str = "none",
):
    """One-token decode against a ring-buffer KV cache.

    cache: {"k": (B,W,nkv,hd), "v": (B,W,nkv,hd), "pos": int32 (-1 = empty)}.
    ``index``: absolute position of the new token — either a scalar (all
    sequences at the same position, pos (W,)) or a (B,) vector for
    continuous batching (each batch row is an independent request slot at
    its own position; pos is then per-slot (B, W) — see repro.serve)."""
    b = x.shape[0]
    per_slot = jnp.ndim(index) == 1
    if per_slot:
        pos_new = index.reshape(b, 1).astype(jnp.int32)
    else:
        pos_new = jnp.full((b, 1), index, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, pos_new, quant)
    ctx, new_cache = _attend_one(q, k_new, v_new, x.dtype, cfg, cache,
                                 index, window)
    out = constrain(ctx, ("batch", "seq", "q_dim"))
    out = quantized_matmul(out, p["wo"], quant, cfg.quant_format)
    return out, new_cache


def attention_prefill(
    p: dict, x: jax.Array, cfg, cache: dict, index: jax.Array,
    lengths: jax.Array, window=None, quant: str = "none",
):
    """Chunked-prefill attention: up to T new tokens per slot against the
    per-slot paged cache in one call.

    x (B,T,d); row b's valid tokens are ``x[b, :lengths[b]]`` at absolute
    positions ``index[b] .. index[b]+lengths[b]-1`` (``lengths`` may be 0
    for idle rows — their cache rows stay untouched and their outputs are
    garbage for the caller to discard). The QKV and output projections run
    ONCE over the whole chunk — the packed M2XFP weight streams cross HBM
    once per chunk instead of once per token — while the cache write +
    attend runs as a lax.scan of the exact single-token decode step
    (write-then-attend per position, which also keeps ring-buffer overwrite
    semantics exact for sliding windows narrower than the chunk), so every
    position's output is bit-identical to T sequential ``attention_decode``
    calls. Returns (out (B,T,d), new cache)."""
    if jnp.ndim(index) != 1:
        raise ValueError("attention_prefill needs per-slot caches "
                         "((B,) index vector)")
    t = x.shape[1]
    offs = jnp.arange(t, dtype=jnp.int32)
    positions = index[:, None] + offs[None, :]               # (B, T)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, quant)

    def step(cache, xs):
        q_t, k_t, v_t, off = xs
        ctx, cache = _attend_one(q_t, k_t, v_t, x.dtype, cfg, cache,
                                 index + off, window, valid=off < lengths)
        return cache, ctx

    # (B,T,...) -> per-position (B,1,...) scan slices, chunk axis leading
    xs = tuple(jnp.moveaxis(a, 1, 0)[:, :, None] for a in (q, k_new, v_new))
    cache, ctxs = jax.lax.scan(step, cache, xs + (offs,))
    out = jnp.moveaxis(ctxs[:, :, 0], 0, 1)                  # (B,T,nh*hd)
    out = constrain(out, ("batch", "seq", "q_dim"))
    out = quantized_matmul(out, p["wo"], quant, cfg.quant_format)
    return out, cache


def init_cache(cfg, batch: int, max_len: int, window: Optional[int] = None,
               dtype=jnp.bfloat16, per_slot: bool = False) -> dict:
    """Empty ring-buffer cache. Size = min(window, max_len) when windowed.
    cfg.kv_quant != 'none': K/V stored as the named codec's packed streams
    (Sec. 6.4 — e.g. 'm2xfp' = Sg-EM at 4.5 bits/elem resident; any codec
    in ``repro.core.codecs.kv_codecs()``).

    ``per_slot=True`` gives the paged layout used by the serving engine:
    positions are tracked per batch row ((B, W) instead of (W,)) so each
    row is an independently admitted/evicted request slot, and
    ``attention_decode`` must then be called with a (B,) index vector."""
    w = min(window, max_len) if window else max_len
    pos_shape = (batch, w) if per_slot else (w,)
    if cfg.kv_quant != "none":
        from .kvquant import kv_cache_spec
        return {
            "k": kv_cache_spec(batch, w, cfg.n_kv_heads, cfg.hd,
                               cfg.kv_quant),
            "v": kv_cache_spec(batch, w, cfg.n_kv_heads, cfg.hd,
                               cfg.kv_quant),
            "pos": jnp.full(pos_shape, -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.full(pos_shape, -1, jnp.int32),
    }


def cache_from_prefill(k: jax.Array, v: jax.Array, positions: jax.Array,
                       window: Optional[int] = None) -> dict:
    """Build a decode cache from prefill K/V (keeps the trailing window)."""
    s = k.shape[1]
    w = min(window, s) if window else s
    # ring layout: slot = pos % w; for contiguous positions [s-w, s) this is
    # a roll of the trailing slice
    k_t, v_t = k[:, s - w:], v[:, s - w:]
    pos_t = positions[0, s - w:]
    shift = jnp.mod(pos_t[0], w)
    k_r = jnp.roll(k_t, shift, axis=1)
    v_r = jnp.roll(v_t, shift, axis=1)
    pos_r = jnp.roll(pos_t, shift, axis=0)
    return {"k": k_r, "v": v_r, "pos": pos_r}
