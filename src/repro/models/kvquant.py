"""M2XFP KV-cache quantization (paper Sec. 6.4).

K/V are right-hand GEMM operands (P = Q K^T, O = P V), so per the paper the
Sg-EM weight-style format applies to them: groups of 32 along head_dim with
an E8M0 scale + 2-bit subgroup multipliers -> 4.5 bits/element resident
instead of 16. The decode write path quantizes each new token's K/V online
(fixed-scale Sg-EM: the 4-candidate multiplier search is cheap and
deterministic); reads dequantize inline before the attention contractions.

Capacity win: 3.55x smaller KV cache (e.g. musicgen-large decode_32k:
21.5 -> ~8 GiB/device). Traffic win additionally requires fusing the decode
into the attention kernel (the Pallas m2xfp kernels demonstrate the decode
path in-kernel; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dtypes import exp2int, round_to_grid, FP4_E2M1, \
    fp4_value_to_code, fp4_code_to_value
from repro.core.m2xfp import sg_em_dequant_with_scale
from repro.core.packing import (
    group_reshape, pack_meta2, pack_nibbles, unpack_meta2, unpack_nibbles,
)
from repro.core.scaling import e8m0_decode, e8m0_encode, shared_scale_exponent

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

__all__ = ["kv_encode", "kv_decode", "kv_cache_spec", "kv_page_write"]


def kv_encode(x: jax.Array) -> dict:
    """(..., hd) -> {codes (..., hd/2) u8, scales (..., hd/32) u8,
    meta (..., hd/32) u8}. Sg-EM fixed-scale (online-cheap).

    With the ``health`` pillar of REPRO_OBS enabled at trace time, clip /
    scale-saturation / meta-mode reductions over the encoded tokens are
    traced in and drained host-side asynchronously (repro.obs.quant_health
    — the encoder's own intermediates are reused, so the probe adds only
    small reductions)."""
    from repro.obs.quant_health import probe_scaled
    hd = x.shape[-1]
    xg = group_reshape(x.astype(jnp.float32), GROUP)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = shared_scale_exponent(amax, "floor")
    s = exp2int(e)
    _, k_sel, _ = sg_em_dequant_with_scale(
        xg, s, SUBGROUP, bits=2, adaptive=False, return_codes=True)
    s_final = (1.0 + k_sel.astype(jnp.float32) / 4.0) * s
    xsub = xg.reshape(*xg.shape[:-1], N_SUB, SUBGROUP)
    probe_scaled("kv_encode", xsub / s_final[..., None], e, k_sel)
    q = round_to_grid(xsub / s_final[..., None], FP4_E2M1)
    mag = fp4_value_to_code(jnp.abs(q))
    codes = jnp.where(xsub < 0, mag | 8, mag).reshape(*x.shape[:-1], hd)
    return {
        "codes": pack_nibbles(codes),
        "scales": e8m0_encode(e[..., 0]),
        "meta": pack_meta2(k_sel.reshape(*x.shape[:-1], -1)),
    }


def kv_decode(p: dict) -> jax.Array:
    """Inverse of kv_encode -> bf16 (..., hd)."""
    codes = unpack_nibbles(p["codes"])
    hd = codes.shape[-1]
    mag = fp4_code_to_value(codes & 7)
    sign = jnp.where((codes & 8) != 0, -1.0, 1.0)
    s = e8m0_decode(p["scales"])[..., None]                  # (..., ng, 1)
    k = unpack_meta2(p["meta"], (hd // GROUP) * N_SUB)
    mult = 1.0 + k.astype(jnp.float32) / 4.0
    vals = (mag * sign).reshape(*codes.shape[:-1], hd // GROUP, N_SUB,
                                SUBGROUP)
    out = vals * mult.reshape(*codes.shape[:-1], hd // GROUP, N_SUB, 1) \
        * s[..., None]
    return out.reshape(*codes.shape[:-1], hd).astype(jnp.bfloat16)


def kv_page_write(page: dict, enc: dict, slot: jax.Array,
                  valid: jax.Array | None = None) -> dict:
    """Vectorized per-slot ring write of one encoded token per batch row.

    ``page``: a packed K or V page — {"codes", "scales", "meta"} u8 streams
    with leading (B, W) axes. ``enc``: ``kv_encode`` output with leading
    (B, 1). ``slot`` (B,): ring offset per row (``index % W``). ``valid``
    (B,) bool, optional: rows with False keep their page bytes untouched —
    the masked write the chunked-prefill path uses for positions past a
    slot's chunk length. Returns the updated page dict."""
    def write(buf, new):
        upd = jax.vmap(
            lambda b, n, s: jax.lax.dynamic_update_slice(
                b, n.astype(b.dtype), (s,) + (0,) * (b.ndim - 1))
        )(buf, new, slot)
        if valid is None:
            return upd
        return jnp.where(
            valid.reshape((-1,) + (1,) * (buf.ndim - 1)), upd, buf)

    return {key: write(page[key], enc[key])
            for key in ("codes", "scales", "meta")}


def kv_cache_spec(batch: int, w: int, nkv: int, hd: int) -> dict:
    return {
        "codes": jnp.zeros((batch, w, nkv, hd // 2), jnp.uint8),
        "scales": jnp.zeros((batch, w, nkv, hd // GROUP), jnp.uint8),
        "meta": jnp.zeros((batch, w, nkv, hd // GROUP), jnp.uint8),
    }
