"""Quantized KV cache (paper Sec. 6.4) — codec-dispatched.

K/V are right-hand GEMM operands (P = Q K^T, O = P V), so the weight-style
packed formats apply to them: groups along head_dim with a shared scale
(+ metadata for m2xfp) resident instead of 16-bit. The decode write path
quantizes each new token's K/V online (for m2xfp: fixed-scale Sg-EM — the
4-candidate multiplier search is cheap and deterministic); reads dequantize
inline before the attention contractions.

Which codecs can back the cache is a registry property (``kv_codecs()``):
the encode must be *order-independent* so chunked prefill and sequential
decode write identical pages. m2xfp (4.5 bits/elem, 3.55x smaller cache —
e.g. musicgen-large decode_32k: 21.5 -> ~8 GiB/device) and mxfp4 (4.25)
qualify; nvfp4 does not (its per-call tensor scale depends on the batch of
values seen together) and asking for it raises an actionable error.

Traffic wins additionally require fusing the decode into the attention
kernel (the Pallas m2xfp kernels demonstrate the decode path in-kernel; see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codecs import get_codec, kv_codecs

GROUP = 32
SUBGROUP = 8
N_SUB = GROUP // SUBGROUP

__all__ = ["kv_codec", "kv_encode", "kv_decode", "kv_cache_spec",
           "kv_page_write"]


def kv_codec(fmt: str):
    """Resolve ``fmt`` to a codec with a packed KV path, or raise with the
    list of codecs that have one."""
    codec = get_codec(fmt)
    if not codec.kv_capable:
        raise ValueError(
            f"codec {fmt!r} has no packed KV-cache path (its encode is not "
            f"order-independent or not implemented); KV-capable codecs: "
            f"{', '.join(kv_codecs())}")
    return codec


def kv_encode(x: jax.Array, fmt: str = "m2xfp") -> dict:
    """(..., hd) -> packed stream dict (for m2xfp: codes (..., hd/2) u8,
    scales (..., hd/32) u8, meta (..., hd/32) u8).

    With the ``health`` pillar of REPRO_OBS enabled at trace time, clip /
    scale-saturation / meta-mode reductions over the encoded tokens are
    traced in and drained host-side asynchronously (repro.obs.quant_health
    — the encoder's own intermediates are reused, so the probe adds only
    small reductions)."""
    return kv_codec(fmt).kv_encode(x)


def kv_decode(p: dict, fmt: str = "m2xfp") -> jax.Array:
    """Inverse of kv_encode -> bf16 (..., hd)."""
    return kv_codec(fmt).kv_decode(p)


def kv_page_write(page: dict, enc: dict, slot: jax.Array,
                  valid: jax.Array | None = None) -> dict:
    """Vectorized per-slot ring write of one encoded token per batch row.

    ``page``: a packed K or V page — dict of u8 streams with leading (B, W)
    axes (whatever streams the codec defines). ``enc``: ``kv_encode``
    output with leading (B, 1). ``slot`` (B,): ring offset per row
    (``index % W``). ``valid`` (B,) bool, optional: rows with False keep
    their page bytes untouched — the masked write the chunked-prefill path
    uses for positions past a slot's chunk length. Returns the updated
    page dict."""
    def write(buf, new):
        upd = jax.vmap(
            lambda b, n, s: jax.lax.dynamic_update_slice(
                b, n.astype(b.dtype), (s,) + (0,) * (b.ndim - 1))
        )(buf, new, slot)
        if valid is None:
            return upd
        return jnp.where(
            valid.reshape((-1,) + (1,) * (buf.ndim - 1)), upd, buf)

    return {key: write(page[key], enc[key]) for key in page}


def kv_cache_spec(batch: int, w: int, nkv: int, hd: int,
                  fmt: str = "m2xfp") -> dict:
    """Zero-initialized packed K or V page for ``fmt``."""
    return kv_codec(fmt).kv_spec(batch, w, nkv, hd)
