"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with true recurrence, sequential scan).

mLSTM cell (exponential gating, stabilized):
    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = e^{logf+m_{t-1}-m_t} C_{t-1} + e^{logi-m_t} v k^T
    n_t = e^{logf+m_{t-1}-m_t} n_{t-1} + e^{logi-m_t} k
    h_t = (C_t q_t) / max(|n_t . q_t|, e^{-m_t})

Training/prefill uses the chunkwise-parallel form (intra-chunk quadratic
attention-like scores + inter-chunk (C, n, m) carry through lax.scan) so the
backward pass does not store O(S) matrix states. Decode is the single-step
recurrence (constant state -> `long_500k` capable).

sLSTM keeps per-head recurrent weights (block-diagonal R) and therefore runs
as a sequential lax.scan in both directions; its state is O(d), which is
cheap even at 500k contexts.

Projections go through ``quantized_matmul`` (M2XFP applies to GEMM
operands); cell math stays f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .quant import init_linear, quantized_matmul

MLSTM_CHUNK = 128


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg):
    din = 2 * cfg.d_model
    h = cfg.n_heads
    return din, h, din // h


def init_mlstm(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    din, h, p_ = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    blk = lambda k: (jax.random.normal(k, (h, p_, p_), jnp.float32)
                     * p_ ** -0.5).astype(dtype)
    return {
        "up": init_linear(ks[0], d, 2 * din, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (4, din), jnp.float32) * 0.5)
            .astype(jnp.float32),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "wq": blk(ks[2]), "wk": blk(ks[3]), "wv": blk(ks[4]),
        "w_if": init_linear(ks[5], din, 2 * h, dtype=jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((h,)), jnp.linspace(3.0, 6.0, h)]).astype(jnp.float32),
        "w_o": init_linear(ks[6], d, din, dtype=dtype),
        "gn": jnp.ones((din,), jnp.float32),
        "down": init_linear(ks[7], din, d, dtype=dtype),
    }


def _conv4(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(out)


def _mlstm_qkv(p, x_norm, cfg, quant):
    """Shared front half: projections, conv, gates. x_norm: (B,S,D)."""
    din, h, p_ = _mlstm_dims(cfg)
    b, s, _ = x_norm.shape
    up = quantized_matmul(x_norm, p["up"], quant, cfg.quant_format)
    xin, z = jnp.split(up, 2, axis=-1)
    xc = _conv4(xin, p["conv_w"], p["conv_b"])               # (B,S,din) f32
    xch = xc.reshape(b, s, h, p_)
    xinh = xin.astype(jnp.float32).reshape(b, s, h, p_)
    q = jnp.einsum("bshp,hpq->bshq", xch, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bshp,hpq->bshq", xch, p["wk"].astype(jnp.float32)) \
        * (p_ ** -0.5)
    v = jnp.einsum("bshp,hpq->bshq", xinh, p["wv"].astype(jnp.float32))
    gates = xc @ p["w_if"] + p["b_if"]                        # (B,S,2H)
    logi = gates[..., :h]
    logf = jax.nn.log_sigmoid(gates[..., h:])
    o = jax.nn.sigmoid(
        quantized_matmul(x_norm, p["w_o"], quant, cfg.quant_format)
        .astype(jnp.float32))
    return xin, z, q, k, v, logi, logf, o


def _mlstm_cell_chunkwise(q, k, v, logi, logf):
    """Chunkwise-parallel stabilized mLSTM. q/k/v: (B,S,H,P); gates (B,S,H).
    Returns h (B,S,H,P) and final (C, n, m) state."""
    b, s, h, p_ = q.shape
    l = min(MLSTM_CHUNK, s)
    nc = s // l
    qc = q.reshape(b, nc, l, h, p_)
    kc = k.reshape(b, nc, l, h, p_)
    vc = v.reshape(b, nc, l, h, p_)
    li = logi.reshape(b, nc, l, h)
    lf = logf.reshape(b, nc, l, h)
    fcum = jnp.cumsum(lf, axis=2)                             # F_t
    g = li - fcum                                             # g_s = li_s - F_s
    gmax_run = jax.lax.cummax(g, axis=2)                      # cummax_s<=t g_s
    g_end = jnp.max(g, axis=2)                                # (B,nc,H)
    f_end = fcum[:, :, -1]                                    # (B,nc,H)

    # intra-chunk scores (computed once; combined with carry inside scan)
    qk = jnp.einsum("bclhp,bcmhp->bclmh", qc, kc)             # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))

    def chunk_step(carry, inp):
        c_st, n_st, m_c = carry          # (B,H,P,P), (B,H,P), (B,H)
        qcc, kcc, vcc, fc, gc, gmx, qkc, ge, fe = inp
        mu = jnp.maximum(m_c[:, None], gmx)                   # (B,L,H)
        # intra exponent for (t, s): F_t - F_s + li_s - m_t = g_s - mu_t
        # (masked inside the exp: masked entries can be large-positive and
        # an inf forward value NaNs the backward via inf * 0)
        expo = gc[:, None, :, :] - mu[:, :, None, :]           # (B,L_t,L_s,H)
        w_st = jnp.exp(jnp.where(mask[None, :, :, None], expo, -1e9))
        num_intra = jnp.einsum("blmh,blmh,bmhp->blhp", qkc, w_st, vcc)
        den_intra = jnp.einsum("blmh,blmh->blh", qkc, w_st)
        # inter: carry state decayed by exp(F_t + m_c - m_t)
        w_in = jnp.exp(m_c[:, None] - mu)                     # (B,L,H)
        num_inter = jnp.einsum("blhp,bhpq->blhq", qcc, c_st) * w_in[..., None]
        den_inter = jnp.einsum("blhp,bhp->blh", qcc, n_st) * w_in
        m_t = fc + mu
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h_out = (num_intra + num_inter) / den[..., None]
        # carry update to chunk end
        m_next = fe + jnp.maximum(m_c, ge)
        cd = jnp.exp(m_c + fe - m_next)                       # (B,H)
        wk_end = jnp.exp(fe[:, None] + gc - m_next[:, None])  # (B,L,H)
        c_new = c_st * cd[:, :, None, None] + jnp.einsum(
            "blh,blhp,blhq->bhpq", wk_end, kcc, vcc)
        n_new = n_st * cd[:, :, None] + jnp.einsum(
            "blh,blhp->bhp", wk_end, kcc)
        return (c_new, n_new, m_next), h_out

    init = (jnp.zeros((b, h, p_, p_), jnp.float32),
            jnp.zeros((b, h, p_), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), fcum.transpose(1, 0, 2, 3),
          g.transpose(1, 0, 2, 3), gmax_run.transpose(1, 0, 2, 3),
          qk.transpose(1, 0, 2, 3, 4), g_end.transpose(1, 0, 2),
          f_end.transpose(1, 0, 2))
    (c_f, n_f, m_f), hs = jax.lax.scan(chunk_step, init, xs)
    hseq = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_)
    return hseq, {"C": c_f, "n": n_f, "m": m_f}


def mlstm_forward(p, x, cfg, quant="none"):
    """Full-sequence mLSTM block (pre-norm residual handled by caller).
    x: (B,S,D) normalized input. Returns (out, cache)."""
    din, h, p_ = _mlstm_dims(cfg)
    b, s, _ = x.shape
    xin, z, q, k, v, logi, logf, o = _mlstm_qkv(p, x, cfg, quant)
    hseq, state = _mlstm_cell_chunkwise(q, k, v, logi, logf)
    hflat = (hseq.reshape(b, s, din) * o)
    hflat = rms_norm(hflat, p["gn"], cfg.norm_eps)
    out = hflat.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = quantized_matmul(out, p["down"], quant, cfg.quant_format)
    k_ = p["conv_w"].shape[0]
    state["conv"] = xin.astype(jnp.float32)[:, s - (k_ - 1):, :]
    return out, state


def init_mlstm_cache(cfg, batch: int) -> dict:
    din, h, p_ = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, p_, p_), jnp.float32),
        "n": jnp.zeros((batch, h, p_), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, din), jnp.float32),
    }


def mlstm_decode(p, x, cfg, cache, quant="none"):
    """Single-token mLSTM step. x: (B,1,D) normalized."""
    din, h, p_ = _mlstm_dims(cfg)
    b = x.shape[0]
    up = quantized_matmul(x, p["up"], quant, cfg.quant_format)[:, 0]
    xin, z = jnp.split(up, 2, axis=-1)
    win = jnp.concatenate(
        [cache["conv"], xin.astype(jnp.float32)[:, None, :]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"])
    xch = xc.reshape(b, h, p_)
    xinh = xin.astype(jnp.float32).reshape(b, h, p_)
    q = jnp.einsum("bhp,hpq->bhq", xch, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bhp,hpq->bhq", xch, p["wk"].astype(jnp.float32)) \
        * (p_ ** -0.5)
    v = jnp.einsum("bhp,hpq->bhq", xinh, p["wv"].astype(jnp.float32))
    gates = xc @ p["w_if"] + p["b_if"]
    logi, logf = gates[:, :h], jax.nn.log_sigmoid(gates[:, h:])
    m_new = jnp.maximum(logf + cache["m"], logi)
    wf = jnp.exp(logf + cache["m"] - m_new)
    wi = jnp.exp(logi - m_new)
    c_new = cache["C"] * wf[..., None, None] + wi[..., None, None] * \
        jnp.einsum("bhp,bhq->bhpq", k, v)
    n_new = cache["n"] * wf[..., None] + wi[..., None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new)),
                      jnp.exp(-m_new))
    hvec = (num / den[..., None]).reshape(b, din)
    o = jax.nn.sigmoid(
        quantized_matmul(x, p["w_o"], quant, cfg.quant_format)[:, 0]
        .astype(jnp.float32))
    hvec = rms_norm(hvec * o, p["gn"], cfg.norm_eps)
    out = hvec[:, None, :].astype(x.dtype) * \
        jax.nn.silu(z.astype(jnp.float32))[:, None, :].astype(x.dtype)
    out = quantized_matmul(out, p["down"], quant, cfg.quant_format)
    return out, {"C": c_new, "n": n_new, "m": m_new, "conv": win[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p_ = d // h
    ff = int(d * 4 / 3 + 63) // 64 * 64
    ks = jax.random.split(key, 5)
    return {
        "w": init_linear(ks[0], d, 4 * d, dtype=dtype),          # z,i,f,o
        "r": (jax.random.normal(ks[1], (4, h, p_, p_), jnp.float32)
              * p_ ** -0.5).astype(jnp.float32),
        "b": jnp.concatenate([
            jnp.zeros((2 * d,)),
            jnp.ones((d,)) * 3.0,                                # f bias
            jnp.zeros((d,))]).astype(jnp.float32),
        "gn": jnp.ones((d,), jnp.float32),
        "ff_up": init_linear(ks[3], d, ff, dtype=dtype),
        "ff_down": init_linear(ks[4], ff, d, dtype=dtype),
    }


def _slstm_step(p, cfg, carry, wx_t):
    """One sLSTM timestep. carry: (c, n, h, m) each (B, d)."""
    d = cfg.d_model
    nh = cfg.n_heads
    p_ = d // nh
    c, n, hprev, m = carry
    hh = hprev.reshape(-1, nh, p_)
    rec = jnp.stack([
        jnp.einsum("bhp,hpq->bhq", hh, p["r"][j]) for j in range(4)
    ], axis=1).reshape(-1, 4 * d)                            # (B, 4d)
    pre = wx_t + rec + p["b"]
    zt = jnp.tanh(pre[:, :d])
    logi = pre[:, d:2 * d]
    logf = jax.nn.log_sigmoid(pre[:, 2 * d:3 * d])
    ot = jax.nn.sigmoid(pre[:, 3 * d:])
    m_new = jnp.maximum(logf + m, logi)
    wf = jnp.exp(logf + m - m_new)
    wi = jnp.exp(logi - m_new)
    c_new = wf * c + wi * zt
    n_new = wf * n + wi
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(p, x, cfg, quant="none"):
    """Full-sequence sLSTM block. x: (B,S,D) normalized. (out, cache)."""
    b, s, d = x.shape
    wx = quantized_matmul(x, p["w"], quant, cfg.quant_format) \
        .astype(jnp.float32)                                  # (B,S,4d)
    init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + (
        jnp.full((b, d), -1e30, jnp.float32),)

    def step(carry, wx_t):
        new = _slstm_step(p, cfg, carry, wx_t)
        return new, new[2]

    carry, hs = jax.lax.scan(step, init, wx.transpose(1, 0, 2))
    hseq = hs.transpose(1, 0, 2)                              # (B,S,d)
    hseq = rms_norm(hseq, p["gn"], cfg.norm_eps).astype(x.dtype)
    ff = quantized_matmul(hseq, p["ff_up"], quant, cfg.quant_format)
    ff = jax.nn.gelu(ff.astype(jnp.float32)).astype(x.dtype)
    out = quantized_matmul(ff, p["ff_down"], quant, cfg.quant_format)
    return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}


def init_slstm_cache(cfg, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def slstm_decode(p, x, cfg, cache, quant="none"):
    """Single-token sLSTM step. x: (B,1,D) normalized."""
    wx = quantized_matmul(x, p["w"], quant, cfg.quant_format)[:, 0] \
        .astype(jnp.float32)
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_step(p, cfg, carry, wx)
    hseq = rms_norm(h[:, None, :], p["gn"], cfg.norm_eps).astype(x.dtype)
    ff = quantized_matmul(hseq, p["ff_up"], quant, cfg.quant_format)
    ff = jax.nn.gelu(ff.astype(jnp.float32)).astype(x.dtype)
    out = quantized_matmul(ff, p["ff_down"], quant, cfg.quant_format)
    return out, {"c": c, "n": n, "h": h, "m": m}
