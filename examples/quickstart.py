"""Quickstart: quantize tensors with every format, inspect the M2XFP
encoding, and run the Pallas kernels.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    encode_act_m2xfp, format_ebw, quantize_act_m2xfp, quantize_mxfp4,
    quantize_nvfp4, quantize_smx4, quantize_weight_m2xfp, run_strategy,
)
from repro.kernels import m2xfp_matmul, m2xfp_quantize, pack_w_sgem


def main():
    rng = np.random.default_rng(0)
    # LLM-like tensor: heavy-tailed with outlier channels
    x = jnp.asarray(rng.standard_t(4, (256, 1024)).astype(np.float32)
                    * np.exp(0.8 * rng.standard_normal((1, 1024))
                             ).astype(np.float32))

    print("== format comparison (MSE vs f32, lower is better) ==")
    for name, fn in [
        ("mxfp4   (EBW 4.25)", quantize_mxfp4),
        ("nvfp4   (EBW 4.50)", quantize_nvfp4),
        ("smx4    (EBW 4.00)", quantize_smx4),
        ("m2xfp-A (EBW 4.50)", quantize_act_m2xfp),
        ("m2xfp-W (EBW 4.50)", quantize_weight_m2xfp),
    ]:
        print(f"  {name}: {float(jnp.mean((fn(x) - x) ** 2)):.5f}")

    print("\n== packed M2XFP layout (paper Sec. 5.2) ==")
    p = encode_act_m2xfp(x)
    print(f"  codes {p.codes.shape} u8 + scale {p.scale.shape} u8 "
          f"+ meta {p.meta.shape} u8 = {p.nbytes_per_elem * 8:.2f} bits/elem")

    print("\n== DSE strategies at subgroup 8 (paper Figs. 6-7) ==")
    for s in ("elem_em_top1", "sg_em_2bit", "sg_em_2bit_adaptive",
              "sg_ee_2bit"):
        dq, ebw = run_strategy(s, x, subgroup=8)
        print(f"  {s:22s} EBW={ebw:.3f}  MSE={float(jnp.mean((dq-x)**2)):.5f}")

    print("\n== Pallas kernels (interpret mode on CPU; Mosaic on TPU) ==")
    w = jnp.asarray(rng.standard_normal((1024, 128)).astype(np.float32) * .05)
    wp = pack_w_sgem(w)
    out = m2xfp_matmul(x[:128], wp)
    xq = m2xfp_quantize(x[:128, :512])
    print(f"  fused dequant-GEMM out: {out.shape} {out.dtype}")
    print(f"  online quantize streams: codes {xq['codes'].shape}, "
          f"scales {xq['scales'].shape}, meta {xq['meta'].shape}")


if __name__ == "__main__":
    main()
