"""Serving driver — thin wrapper over the packed-weight engine.

Pipeline (the paper's deployment path, repro.serve):
  1. offline prequantization: bf16 params -> the packed streams of the
     --fmt codec (m2xfp: Sg-EM, 4.5 bits/element resident; any packable
     repro.core.codecs entry; weights never rematerialize in bf16),
     round-tripped through a packed checkpoint;
  2. continuous-batching decode: requests with different prompt lengths
     share the batch, admitted/evicted per slot while the engine keeps
     stepping (quantized KV-cache pages with --kv-quant).

    PYTHONPATH=src python examples/serve_quantized.py --tokens 16
    PYTHONPATH=src python examples/serve_quantized.py --fmt nvfp4
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.core.codecs import packed_codecs
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    ServeEngine, load_packed_checkpoint, prequantize_params,
    save_packed_checkpoint, tree_nbytes,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="m2xfp", choices=list(packed_codecs()),
                    help="packed weight codec served from the checkpoint")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 32,
        n_kv_heads=args.d_model // 64, d_ff=3 * args.d_model,
        vocab_size=4096, remat=False, quant="serve",
        quant_format=args.fmt,
        kv_quant="m2xfp" if args.kv_quant else "none")

    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = prequantize_params(params, cfg)
    print(f"weights: {tree_nbytes(params) / 2**20:.1f} MiB bf16 -> "
          f"{tree_nbytes(packed) / 2**20:.1f} MiB packed {args.fmt}")

    # the engine loads from the packed checkpoint, proving bf16 weights are
    # not needed at serving time
    with tempfile.TemporaryDirectory() as ckdir:
        save_packed_checkpoint(ckdir, packed, cfg)
        served, _ = load_packed_checkpoint(ckdir, cfg)

    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in rng.integers(args.prompt_len // 2,
                                     args.prompt_len + 1, args.requests)]
    eng = ServeEngine(served, cfg, n_slots=args.slots,
                      max_len=args.prompt_len + args.tokens)
    outputs = eng.generate(prompts, max_new_tokens=args.tokens)

    s = eng.stats
    print(f"served {len(prompts)} requests on {args.slots} slots in "
          f"{s.steps} steps / {s.wall_s:.2f}s — "
          f"{s.tokens_per_sec:.1f} tok/s on {jax.default_backend()}, "
          f"slot occupancy {s.occupancy:.2f}")
    print("sample output:", outputs[0])


if __name__ == "__main__":
    main()
