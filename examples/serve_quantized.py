"""Serving driver: load (or train) a model, pack weights to M2XFP
(4.5 bits/element resident), and serve batched autoregressive generation
against the ring-buffer KV cache — the paper's deployment path.

    PYTHONPATH=src python examples/serve_quantized.py --tokens 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step, forward, init_caches, init_params, pack_params_for_serving,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 32,
        n_kv_heads=args.d_model // 64, d_ff=3 * args.d_model,
        vocab_size=4096, remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)

    scfg = dataclasses.replace(cfg, quant="serve")
    packed = pack_params_for_serving(params, scfg)

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    print(f"weights: {nbytes(params)/2**20:.1f} MiB bf16 -> "
          f"{nbytes(packed)/2**20:.1f} MiB packed M2XFP")

    data = SyntheticLM(DataConfig(batch=args.batch, seq=args.prompt_len,
                                  vocab=cfg.vocab_size, seed=5))
    prompts = jnp.asarray(data.batch_at(0)["tokens"])
    max_len = args.prompt_len + args.tokens

    # prefill by teacher-forcing the prompt through decode steps (simple,
    # exercises the exact serving path; a production prefill uses forward())
    caches = init_caches(scfg, args.batch, max_len)
    step = jax.jit(lambda p, b, c, i: decode_step(p, scfg, b, c, i))
    tok = prompts[:, :1]
    generated = [tok]
    t0 = time.perf_counter()
    for t in range(max_len - 1):
        logits, caches = step(packed, {"tokens": tok}, caches, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = prompts[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"generated {args.tokens} tokens x {args.batch} seqs in "
          f"{dt:.2f}s ({args.batch * (max_len-1) / dt:.1f} tok/s on CPU)")
    print("sample row:", np.asarray(out[0, -args.tokens:]))


if __name__ == "__main__":
    main()
