"""End-to-end training driver: a ~100M-class LM (reduced here to run on
CPU; pass --d-model/--layers to scale up) on the deterministic synthetic
stream, with checkpoint/resume, straggler monitoring, preemption safety,
and optional M2XFP QAT.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --quant qat
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.straggler import PreemptionGuard, StragglerMonitor
from repro.models.config import ModelConfig
from repro.models.model import loss_fn
from repro import obs
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import (make_train_state, make_train_step,
                                 publish_train_metrics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="none", choices=["none", "qat"])
    ap.add_argument("--ckpt-dir", default="experiments/artifacts/train_lm")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 32,
        n_kv_heads=args.d_model // 64, d_ff=3 * args.d_model,
        vocab_size=4096, quant=args.quant, remat=False)
    print(f"model: {cfg.n_params/1e6:.1f}M params, quant={cfg.quant}")

    data = SyntheticLM(DataConfig(batch=args.batch, seq=args.seq,
                                  vocab=cfg.vocab_size, seed=0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      num_microbatches=args.microbatches))

    mgr = CheckpointManager(args.ckpt_dir, every=50, keep=2)
    guard = PreemptionGuard()
    monitor = StragglerMonitor(
        on_straggle=lambda s, dt: print(f"  [straggler] step {s}: {dt:.2f}s"))

    state = make_train_state(jax.random.PRNGKey(0), cfg)
    resumed, extra, ck_step = mgr.resume(state)
    start = 0
    if resumed is not None:
        state, start = resumed, extra["data_step"]
        print(f"resumed from step {ck_step} (data step {start})")

    pf = Prefetcher(data, start_step=start)
    try:
        for i in range(start, args.steps):
            data_step, batch = next(pf)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            monitor.step_start()
            state, metrics = step_fn(state, batch)
            monitor.step_end(i)
            if i % 20 == 0 or i == args.steps - 1:
                publish_train_metrics(metrics, step=i)   # REPRO_OBS-gated
                print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}")
            mgr.maybe_save(i, state, extra={"data_step": data_step + 1})
            if guard.preempted:
                print("preempted — final checkpoint")
                mgr.maybe_save(i, state, extra={"data_step": data_step + 1},
                               force=True)
                break
        mgr.maybe_save(args.steps - 1, state,
                       extra={"data_step": args.steps}, force=True)
        mgr.wait()
    finally:
        pf.close()
    obs.autodump()        # metrics.jsonl + trace.json -> REPRO_OBS_DIR
    print("done.")


if __name__ == "__main__":
    main()
