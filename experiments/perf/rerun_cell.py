"""Hillclimb helper: re-run one dry-run cell with current env levers and
print the roofline terms (reads no stale JSON)."""
import os, sys, json
sys.argv, argv = sys.argv[:1], sys.argv[1:]
arch, shape = argv[0], argv[1]
label = argv[2] if len(argv) > 2 else "exp"
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell
r = run_cell(arch, shape, multi_pod=False, save=False)
if not r["ok"]:
    print("FAIL", r["error"]); sys.exit(1)
rt = r["roofline"]; m = r["memory"]; h = r["hlo_analysis"]
out = {
  "label": label, "arch": arch, "shape": shape,
  "compute_ms": round(rt["compute_s"]*1e3, 2),
  "memory_ms": round(rt["memory_s"]*1e3, 2),
  "collective_ms": round(rt["collective_s"]*1e3, 2),
  "dominant": rt["dominant"], "frac": round(rt["roofline_fraction"], 4),
  "peak_gib": round(m["peak_per_device"]/2**30, 2),
  "coll_kinds_gb": {k: round(v/1e9, 2) for k, v in h["per_kind_bytes"].items()},
  "env": {k: v for k, v in os.environ.items() if k.startswith("REPRO_") and k != "REPRO_FAITHFUL_DOTS"},
}
print(json.dumps(out))
with open(f"experiments/perf/{label}__{arch.replace('.','_')}__{shape}.json", "w") as f:
    json.dump(r | {"label": label}, f, indent=1)
