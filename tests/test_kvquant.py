"""M2XFP KV-cache quantization (paper Sec. 6.4): roundtrip error bounds,
footprint, and decode consistency vs the bf16 cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.kvquant import kv_decode, kv_encode, kv_cache_spec
from repro.models.model import decode_step, init_caches, init_params

KEY = jax.random.PRNGKey(0)


def test_kv_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 7, 4, 64)).astype(np.float32))
    dq = kv_decode(kv_encode(x))
    # Sg-EM fixed: error bounded by one FP4 step at the group scale
    xg = x.reshape(-1, 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    err = jnp.abs(dq.astype(jnp.float32).reshape(-1, 32) - xg)
    assert bool(jnp.all(err <= 0.5 * amax + 1e-6))
    # relative scale: FP4-level
    rel = float(jnp.max(err) / jnp.max(jnp.abs(x)))
    assert rel < 0.2


def test_kv_footprint_is_4p5_bits():
    spec = kv_cache_spec(batch=2, w=16, nkv=4, hd=64)
    total_bits = 8 * sum(np.prod(v.shape) * v.dtype.itemsize
                         for v in spec.values())
    assert total_bits == 4.5 * (2 * 16 * 4 * 64)


def test_decode_with_quantized_cache_tracks_bf16_cache():
    cfg = dataclasses.replace(smoke_config("qwen3-8b"), head_dim=32)
    qcfg = dataclasses.replace(cfg, kv_quant="m2xfp")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)

    def run(c):
        caches = init_caches(c, 2, 8)
        step = jax.jit(lambda p, b, cc, i: decode_step(p, c, b, cc, i))
        outs = []
        for t in range(8):
            lg, caches = step(params, {"tokens": toks[:, t:t + 1]}, caches,
                              jnp.int32(t))
            outs.append(lg)
        return jnp.concatenate(outs, axis=1).astype(jnp.float32)

    base = run(cfg)
    quant = run(qcfg)
    assert not bool(jnp.any(jnp.isnan(quant)))
    a, b = base.ravel(), quant.ravel()
    corr = float(jnp.corrcoef(jnp.stack([a, b]))[0, 1])
    assert corr > 0.9, corr
