"""Serving engine tests: packed-checkpoint bit-exactness, batched-decode
parity vs the single-request serve path, chunked-prefill bit-identity,
scheduler invariants + fuzz."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step, init_caches, init_params, prefill_chunk,
)
from repro.models.quant import PackedWeight
from repro.serve import (
    ServeEngine, SlotScheduler, load_packed_checkpoint, prequantize_params,
    save_packed_checkpoint, tree_nbytes,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="serve-test", family="dense", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=97,
                remat=False, quant="serve")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def packed_model():
    cfg = _cfg()
    params = init_params(KEY, cfg)
    return cfg, params, prequantize_params(params, cfg)


# ---------------------------------------------------------------------------
# Prequantization / packed checkpoints
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_packed_checkpoint_roundtrip_bitexact(packed_model, tmp_path):
    """Packed u8 streams (and residual bf16 leaves) survive save/load
    bit-for-bit — the serving engine never re-quantizes."""
    cfg, _, packed = packed_model
    save_packed_checkpoint(str(tmp_path), packed, cfg)
    packed2, extra = load_packed_checkpoint(str(tmp_path), cfg)
    assert extra["format"] == "mx-packed"
    assert extra["codec"] == "m2xfp"
    flat1 = jax.tree_util.tree_leaves(packed)
    flat2 = jax.tree_util.tree_leaves(packed2)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.smoke
def test_packed_tree_is_4p5_bits_on_gemm_weights(packed_model):
    cfg, params, packed = packed_model
    for node in jax.tree.leaves(
            packed, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(node, PackedWeight):
            n_elems = 2 * node.codes.size
            assert 8 * tree_nbytes(node) / n_elems == 4.5
    # and the packed tree is strictly smaller than the dense one
    assert tree_nbytes(packed) < tree_nbytes(params)


def test_load_rejects_dense_checkpoint(packed_model, tmp_path):
    cfg, params, _ = packed_model
    from repro.checkpoint import save_state
    save_state(str(tmp_path), 0, params)
    with pytest.raises(ValueError, match="not a packed"):
        load_packed_checkpoint(str(tmp_path), cfg)


def test_load_rejects_codec_mismatch(packed_model, tmp_path):
    """A checkpoint packed as m2xfp must not restore under a config that
    expects different streams — the error names both codecs."""
    cfg, _, packed = packed_model
    save_packed_checkpoint(str(tmp_path), packed, cfg)
    other = dataclasses.replace(cfg, quant_format="mxfp4")
    with pytest.raises(ValueError, match="codec 'm2xfp'.*'mxfp4'"):
        load_packed_checkpoint(str(tmp_path), other)


def test_load_rejects_manifest_without_codec(packed_model, tmp_path):
    """A v2 manifest that lost its codec field fails actionably instead of
    guessing."""
    cfg, _, packed = packed_model
    from repro.checkpoint import save_state
    save_state(str(tmp_path), 0, packed,
               extra={"format": "mx-packed", "format_version": 2})
    with pytest.raises(ValueError, match="records no codec"):
        load_packed_checkpoint(str(tmp_path), cfg)


@pytest.mark.parametrize("fmt", ["mxfp4", "nvfp4"])
def test_engine_serves_packed_checkpoint_any_codec(packed_model, tmp_path,
                                                   fmt):
    """End-to-end per codec: prequantize -> save -> load -> generate. The
    engine never sees a dense weight and the loaded tree is codec-tagged."""
    cfg, params, _ = packed_model
    fcfg = dataclasses.replace(cfg, quant_format=fmt)
    save_packed_checkpoint(str(tmp_path), prequantize_params(params, fcfg),
                           fcfg)
    packed, extra = load_packed_checkpoint(str(tmp_path), fcfg)
    assert extra["codec"] == fmt
    leaves = [l for l in jax.tree.leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert leaves and all(l.codec == fmt for l in leaves)
    eng = ServeEngine(packed, fcfg, n_slots=1, max_len=16)
    out = eng.generate([[5, 6, 7]], max_new_tokens=2)
    assert len(out[0]) == 2 and all(0 <= t < cfg.vocab_size for t in out[0])


# ---------------------------------------------------------------------------
# Golden tokens: the m2xfp serve path is pinned bit-exactly
# ---------------------------------------------------------------------------

_GOLDEN_PROMPTS = [[94, 94, 95, 36, 16],
                   [89, 10, 25, 13, 30, 51, 11, 77, 23],
                   [76, 30, 76]]
# captured from the pre-codec-registry serve path (PRNGKey(0) params,
# n_slots=2, max_len=32, prefill_chunk=4, greedy, 6 new tokens) — any
# change to these tokens is a numerics regression in the packed m2xfp
# pipeline, not a refactor
_GOLDEN_M2XFP = [[90, 70, 70, 86, 68, 68],
                 [45, 96, 34, 11, 96, 64],
                 [41, 41, 30, 93, 41, 41]]
_GOLDEN_M2XFP_KVQ = [[90, 6, 38, 86, 6, 29],
                     [45, 96, 64, 64, 75, 3],
                     [30, 5, 64, 39, 39, 5]]


@pytest.mark.smoke
def test_golden_tokens_m2xfp(packed_model):
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32, prefill_chunk=4)
    assert eng.generate(_GOLDEN_PROMPTS, max_new_tokens=6) == _GOLDEN_M2XFP


def test_golden_tokens_m2xfp_quantized_kv(packed_model):
    cfg, params, _ = packed_model
    qcfg = dataclasses.replace(cfg, kv_quant="m2xfp")
    packed = prequantize_params(params, qcfg)
    eng = ServeEngine(packed, qcfg, n_slots=2, max_len=32, prefill_chunk=4)
    assert eng.generate(_GOLDEN_PROMPTS,
                        max_new_tokens=6) == _GOLDEN_M2XFP_KVQ


# ---------------------------------------------------------------------------
# Batched decode parity
# ---------------------------------------------------------------------------

def _serve_single(packed, cfg, prompt, n_new, max_len=32):
    """Reference: one request alone through the scalar-index serve path."""
    caches = init_caches(cfg, 1, max_len)
    step = jax.jit(lambda p, b, c, i: decode_step(p, cfg, b, c, i))
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    out, t = [], 0
    while len(out) < n_new:
        lg, caches = step(packed, {"tokens": tok}, caches, jnp.int32(t))
        t += 1
        if t < len(prompt):
            tok = jnp.asarray([[prompt[t]]], jnp.int32)
        else:
            nxt = int(jnp.argmax(lg[0, -1]))
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    return out


@pytest.mark.smoke
def test_batched_decode_matches_single_request(packed_model):
    """Continuous batching with ragged prompt lengths + slot reuse produces
    exactly the tokens of each request served alone."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (5, 3, 7, 2)]
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=4)
    eng.scheduler.check()
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, cfg, prompt, 4)


def test_batched_decode_parity_with_quantized_kv(packed_model):
    """Same parity holds when KV pages are packed Sg-EM streams."""
    cfg, params, _ = packed_model
    qcfg = dataclasses.replace(cfg, kv_quant="m2xfp")
    packed = prequantize_params(params, qcfg)
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(0, qcfg.vocab_size, n)))
               for n in (4, 6, 3)]
    eng = ServeEngine(packed, qcfg, n_slots=2, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=3)
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, qcfg, prompt, 3)


def test_slot_reuse_does_not_leak_state(packed_model):
    """A request admitted into a reused slot sees a clean page: serving the
    same prompt twice (before/after other traffic) yields identical
    output."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(5)
    probe = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    filler = [list(map(int, rng.integers(0, cfg.vocab_size, 6)))
              for _ in range(3)]
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    first = eng.generate([probe] + filler, max_new_tokens=4)[0]
    again = eng.generate([probe], max_new_tokens=4)[0]
    assert first == again


# ---------------------------------------------------------------------------
# Chunked prefill: bit-identity with the one-token path
# ---------------------------------------------------------------------------

def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("chunk", [1, 3, 8])
@pytest.mark.parametrize("kw", [
    {}, {"kv_quant": "m2xfp"}, {"sliding_window": 4},
], ids=["dense", "kvq", "slide"])
def test_prefill_chunk_bitexact_caches_and_logits(packed_model, chunk, kw):
    """``prefill_chunk`` over T tokens leaves caches AND logits bit-equal
    to T sequential ``decode_step`` calls — including packed Sg-EM KV pages
    and a sliding window narrower than the chunk (ring overwrite order)."""
    cfg, params, _ = packed_model
    qcfg = dataclasses.replace(cfg, **kw)
    packed = prequantize_params(params, qcfg)
    rng = np.random.default_rng(17)
    b, p_len, w = 2, 7, 16
    toks = rng.integers(0, qcfg.vocab_size, (b, p_len)).astype(np.int32)

    # reference: one token at a time through decode_step
    ref_caches = init_caches(qcfg, b, w, per_slot=True)
    ref_logits = None
    for t in range(p_len):
        ref_logits, ref_caches = decode_step(
            packed, qcfg, {"tokens": jnp.asarray(toks[:, t:t + 1])},
            ref_caches, jnp.full((b,), t, jnp.int32))

    # chunked: same tokens in chunks of `chunk`
    caches = init_caches(qcfg, b, w, per_slot=True)
    logits, last_c = None, 0
    for start in range(0, p_len, chunk):
        last_c = min(chunk, p_len - start)
        block = np.zeros((b, chunk), np.int32)
        block[:, :last_c] = toks[:, start:start + last_c]
        logits, caches = prefill_chunk(
            packed, qcfg, {"tokens": jnp.asarray(block)}, caches,
            jnp.full((b,), start, jnp.int32),
            jnp.full((b,), last_c, jnp.int32))
    _assert_trees_equal(caches, ref_caches)
    np.testing.assert_array_equal(np.asarray(logits[:, last_c - 1]),
                                  np.asarray(ref_logits[:, -1]))


def test_prefill_chunk_ragged_lengths(packed_model):
    """One launch, per-slot lengths {1, 3, 8, 0}: every live slot's cache
    rows and last-position logits match a batch that fed exactly that many
    tokens sequentially; the length-0 slot's rows stay bit-equal to init
    (no masked write leaks)."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(23)
    b, t_max, w = 4, 8, 16
    lens = np.array([1, 3, 8, 0], np.int32)
    toks = rng.integers(0, cfg.vocab_size, (b, t_max)).astype(np.int32)

    caches = init_caches(cfg, b, w, per_slot=True)
    logits, caches = prefill_chunk(
        packed, cfg, {"tokens": jnp.asarray(toks)}, caches,
        jnp.zeros((b,), jnp.int32), jnp.asarray(lens))
    lg = np.asarray(logits, np.float32)

    ref_caches = init_caches(cfg, b, w, per_slot=True)
    for t in range(t_max):
        ref_lg, ref_caches = decode_step(
            packed, cfg, {"tokens": jnp.asarray(toks[:, t:t + 1])},
            ref_caches, jnp.full((b,), t, jnp.int32))
        # rows whose chunk ends here: logits and cache rows must match now
        for row in np.flatnonzero(lens == t + 1):
            np.testing.assert_array_equal(
                lg[row, t], np.asarray(ref_lg[:, -1], np.float32)[row])
            for leaf, ref in zip(jax.tree.leaves(caches),
                                 jax.tree.leaves(ref_caches)):
                np.testing.assert_array_equal(np.asarray(leaf[:, row]),
                                              np.asarray(ref[:, row]))
    # length-0 slot: bit-identical to init
    init = init_caches(cfg, b, w, per_slot=True)
    for leaf, ref in zip(jax.tree.leaves(caches), jax.tree.leaves(init)):
        np.testing.assert_array_equal(np.asarray(leaf[:, 3]),
                                      np.asarray(ref[:, 3]))


@pytest.mark.smoke
@pytest.mark.parametrize("chunk", [3, 8])
def test_chunked_engine_matches_one_token_engine(packed_model, chunk):
    """Engine end-to-end: chunked prefill generates exactly the tokens of
    the legacy one-token path (same traffic, same slots)."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(29)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (1, 3, 8, 12, 5)]
    legacy = ServeEngine(packed, cfg, n_slots=2, max_len=32, prefill_chunk=1)
    chunked = ServeEngine(packed, cfg, n_slots=2, max_len=32,
                          prefill_chunk=chunk)
    ref = legacy.generate(prompts, max_new_tokens=4)
    got = chunked.generate(prompts, max_new_tokens=4)
    assert got == ref
    chunked.scheduler.check()
    assert chunked.stats.steps < legacy.stats.steps


def test_chunked_engine_parity_with_quantized_kv_and_window(packed_model):
    cfg, params, _ = packed_model
    qcfg = dataclasses.replace(cfg, kv_quant="m2xfp", sliding_window=6)
    packed = prequantize_params(params, qcfg)
    rng = np.random.default_rng(31)
    prompts = [list(map(int, rng.integers(0, qcfg.vocab_size, n)))
               for n in (9, 2, 7)]
    eng = ServeEngine(packed, qcfg, n_slots=2, max_len=16, prefill_chunk=8)
    outs = eng.generate(prompts, max_new_tokens=3)
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, qcfg, prompt, 3, max_len=16)


def test_prefill_budget_never_starves_decode_or_oldest(packed_model):
    """With a tiny token budget the engine still finishes everything, and
    bit-identically: decode slots always advance, the oldest prefilling
    request always gets at least one token."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(37)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (12, 12, 12)]
    ref = ServeEngine(packed, cfg, n_slots=2, max_len=32,
                      prefill_chunk=1).generate(prompts, max_new_tokens=3)
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32,
                      prefill_chunk=8, prefill_budget=3)
    assert eng.generate(prompts, max_new_tokens=3) == ref
    eng.scheduler.check()


def test_steps_to_first_token_4x_for_128_prompt(packed_model):
    """Acceptance: a 128-token prompt reaches its first sampled token in
    >= 4x fewer engine steps with chunked prefill, identical tokens."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(41)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 128)))
    legacy = ServeEngine(packed, cfg, n_slots=2, max_len=160,
                         prefill_chunk=1)
    chunked = ServeEngine(packed, cfg, n_slots=2, max_len=160,
                          prefill_chunk=8)
    ref = legacy.generate([prompt], max_new_tokens=2)
    got = chunked.generate([prompt], max_new_tokens=2)
    assert got == ref
    ttft_1, ttft_c = legacy.mean_ttft_steps(), chunked.mean_ttft_steps()
    assert ttft_1 == 128 and ttft_c == 16
    assert ttft_1 / ttft_c >= 4.0


def test_recurrent_families_force_one_token_prefill(packed_model):
    cfg, _, _ = packed_model
    scfg = dataclasses.replace(cfg, family="ssm", quant="none",
                               ssm_state=16, ssm_head_dim=16)
    params = init_params(KEY, scfg)
    eng = ServeEngine(params, scfg, n_slots=1, max_len=32, prefill_chunk=8)
    assert eng.chunk == 1
    out = eng.generate([[1, 2, 3, 4]], max_new_tokens=2)
    assert len(out[0]) == 2


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_scheduler_admit_evict_invariants():
    sched = SlotScheduler(3)
    reqs = [sched.submit([1, 2], max_new_tokens=4) for _ in range(5)]
    sched.check()
    admitted = sched.admit(step=0)
    assert [r.rid for r in admitted] == [0, 1, 2]      # FIFO
    assert not sched.free and len(sched.queue) == 2
    sched.check()
    # evicting frees the slot; next admit reuses it for the oldest queued
    slot = reqs[1].slot
    sched.evict(slot, step=7)
    sched.check()
    assert reqs[1].state == "finished" and reqs[1].finish_step == 7
    nxt = sched.admit(step=8)
    assert [r.rid for r in nxt] == [3] and nxt[0].slot == slot
    sched.check()
    # draining everything returns all slots to free
    while sched.has_work:
        for s in list(sched.active):
            sched.evict(s)
        sched.admit()
        sched.check()
    assert sorted(sched.free) == [0, 1, 2]
    assert len(sched.finished) == 5


def test_scheduler_rejects_bad_requests():
    sched = SlotScheduler(1)
    with pytest.raises(ValueError):
        sched.submit([], max_new_tokens=2)
    with pytest.raises(ValueError):
        SlotScheduler(0)


def test_engine_rejects_over_capacity_prompt(packed_model):
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=1, max_len=8)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(list(range(6)), max_new_tokens=6)


def test_eos_stops_generation(packed_model):
    """A request whose sampler emits eos finishes early and frees the
    slot."""
    cfg, _, packed = packed_model

    def always_eos(logits):
        return np.full((logits.shape[0],), 42, np.int32)

    eng = ServeEngine(packed, cfg, n_slots=1, max_len=32,
                      sample_fn=always_eos)
    req = eng.submit([1, 2, 3], max_new_tokens=10, eos_id=42)
    eng.run()
    assert req.output == [42] and req.state == "finished"
    eng.scheduler.check()


# ---------------------------------------------------------------------------
# Stats / accounting
# ---------------------------------------------------------------------------

def test_run_returns_only_this_drain(packed_model):
    """A second submit/run cycle must not re-deliver earlier requests."""
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    r1 = eng.submit([1, 2, 3], max_new_tokens=2)
    first = eng.run()
    assert [r.rid for r in first] == [r1.rid]
    r2 = eng.submit([4, 5], max_new_tokens=2)
    second = eng.run()
    assert [r.rid for r in second] == [r2.rid]


@pytest.mark.parametrize("chunk", [1, 4])
def test_stats_token_accounting(packed_model, chunk):
    """Per request: prompt feeds len(prompt)-1 prefill tokens (the last
    prompt token's step samples) and every output token counts as
    generated — independent of how prefill is chunked."""
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32,
                      prefill_chunk=chunk)
    prompts = [[1, 2, 3, 4], [5, 6]]
    eng.generate(prompts, max_new_tokens=3)
    s = eng.stats
    assert s.generated_tokens == 2 * 3
    assert s.prefill_tokens == sum(len(p) - 1 for p in prompts)
    assert s.steps == s.prefill_steps + s.decode_steps
    # a slot-step consumes >= 1 token; with chunk=1, exactly one
    assert s.slot_steps <= s.prefill_tokens + s.generated_tokens
    if chunk == 1:
        assert s.prefill_tokens + s.generated_tokens == s.slot_steps
        assert s.prefill_steps == 0
    else:
        assert s.prefill_steps > 0
    assert 0 < s.occupancy <= 1


# ---------------------------------------------------------------------------
# Fuzz: randomized traffic against the scheduler and the engine
# ---------------------------------------------------------------------------

@pytest.mark.fuzz
@pytest.mark.parametrize("seed", range(8))
def test_scheduler_fuzz_invariants(seed):
    """Randomized submit/plan/consume/evict traffic. After every operation:
    slots partition free/active, no slot serves two requests, consumed
    never overruns the prompt, occupancy <= 1; at drain every request
    finished with a full output."""
    rng = np.random.default_rng(seed)
    sched = SlotScheduler(int(rng.integers(1, 5)))
    submitted, step = [], 0
    n_to_submit = int(rng.integers(5, 25))
    while len(submitted) < n_to_submit or sched.has_work:
        step += 1
        if len(submitted) < n_to_submit and rng.random() < 0.5:
            n_new = int(rng.integers(1, 4))
            for _ in range(n_new):
                req = sched.submit(
                    list(map(int, rng.integers(0, 97,
                                               int(rng.integers(1, 12))))),
                    max_new_tokens=int(rng.integers(1, 5)))
                submitted.append(req)
            sched.check()
        sched.admit(step)
        sched.check()
        assert sched.occupancy <= 1
        rids = [r.rid for r in sched.active.values()]
        assert len(rids) == len(set(rids)), "slot serves two requests"
        if not sched.active:
            continue
        budget = (None if rng.random() < 0.5
                  else int(rng.integers(1, 9)))
        plan = sched.plan_chunks(int(rng.integers(1, 9)), budget)
        assert set(plan) == set(sched.active)
        # decode slots always progress; so does the oldest prefilling one
        prefilling = sorted(
            (r for r in sched.active.values() if r.phase == "prefill"),
            key=lambda r: (r.admit_step, r.rid))
        for slot, req in sched.active.items():
            if req.phase == "decode":
                assert plan[slot] == 1
            else:
                assert 0 <= plan[slot] <= len(req.prompt) - req.consumed
        if prefilling:
            assert plan[prefilling[0].slot] >= 1
        # consume the plan the way the engine does
        for slot, req in list(sched.active.items()):
            c = plan[slot]
            if c == 0:
                continue
            if req.phase == "prefill":
                req.consumed += c
                if req.consumed < len(req.prompt):
                    continue
            req.output.append(int(rng.integers(0, 97)))
            if req.done:
                sched.evict(slot, step)
        sched.check()
    assert len(sched.finished) == len(submitted)
    for req in submitted:
        assert req.state == "finished"
        assert req.consumed == len(req.prompt)
        assert len(req.output) == req.max_new_tokens


@pytest.mark.fuzz
@pytest.mark.parametrize("seed", [0, 1])
def test_engine_fuzz_matches_single_request(packed_model, seed):
    """Randomized prompt lengths / chunk / budget / slot churn: every
    request's tokens equal serving it alone, and reused slots leak no KV
    state into later requests."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(100 + seed)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(1, 14)))))
               for _ in range(6)]
    eng = ServeEngine(
        packed, cfg, n_slots=int(rng.integers(1, 4)), max_len=32,
        prefill_chunk=int(rng.integers(2, 9)),
        prefill_budget=(None if rng.random() < 0.5
                        else int(rng.integers(1, 10))))
    outs = eng.generate(prompts, max_new_tokens=3)
    eng.scheduler.check()
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, cfg, prompt, 3)
