"""Serving engine tests: packed-checkpoint bit-exactness, batched-decode
parity vs the single-request serve path, scheduler invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import decode_step, init_caches, init_params
from repro.models.quant import PackedWeight
from repro.serve import (
    ServeEngine, SlotScheduler, load_packed_checkpoint, prequantize_params,
    save_packed_checkpoint, tree_nbytes,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="serve-test", family="dense", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=97,
                remat=False, quant="serve")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def packed_model():
    cfg = _cfg()
    params = init_params(KEY, cfg)
    return cfg, params, prequantize_params(params, cfg)


# ---------------------------------------------------------------------------
# Prequantization / packed checkpoints
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_packed_checkpoint_roundtrip_bitexact(packed_model, tmp_path):
    """Packed u8 streams (and residual bf16 leaves) survive save/load
    bit-for-bit — the serving engine never re-quantizes."""
    cfg, _, packed = packed_model
    save_packed_checkpoint(str(tmp_path), packed, cfg)
    packed2, extra = load_packed_checkpoint(str(tmp_path), cfg)
    assert extra["format"] == "m2xfp-packed-v1"
    flat1 = jax.tree_util.tree_leaves(packed)
    flat2 = jax.tree_util.tree_leaves(packed2)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.smoke
def test_packed_tree_is_4p5_bits_on_gemm_weights(packed_model):
    cfg, params, packed = packed_model
    for node in jax.tree.leaves(
            packed, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(node, PackedWeight):
            n_elems = 2 * node.codes.size
            assert 8 * tree_nbytes(node) / n_elems == 4.5
    # and the packed tree is strictly smaller than the dense one
    assert tree_nbytes(packed) < tree_nbytes(params)


def test_load_rejects_dense_checkpoint(packed_model, tmp_path):
    cfg, params, _ = packed_model
    from repro.checkpoint import save_state
    save_state(str(tmp_path), 0, params)
    with pytest.raises(ValueError, match="not a packed"):
        load_packed_checkpoint(str(tmp_path), cfg)


# ---------------------------------------------------------------------------
# Batched decode parity
# ---------------------------------------------------------------------------

def _serve_single(packed, cfg, prompt, n_new, max_len=32):
    """Reference: one request alone through the scalar-index serve path."""
    caches = init_caches(cfg, 1, max_len)
    step = jax.jit(lambda p, b, c, i: decode_step(p, cfg, b, c, i))
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    out, t = [], 0
    while len(out) < n_new:
        lg, caches = step(packed, {"tokens": tok}, caches, jnp.int32(t))
        t += 1
        if t < len(prompt):
            tok = jnp.asarray([[prompt[t]]], jnp.int32)
        else:
            nxt = int(jnp.argmax(lg[0, -1]))
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    return out


@pytest.mark.smoke
def test_batched_decode_matches_single_request(packed_model):
    """Continuous batching with ragged prompt lengths + slot reuse produces
    exactly the tokens of each request served alone."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (5, 3, 7, 2)]
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=4)
    eng.scheduler.check()
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, cfg, prompt, 4)


def test_batched_decode_parity_with_quantized_kv(packed_model):
    """Same parity holds when KV pages are packed Sg-EM streams."""
    cfg, params, _ = packed_model
    qcfg = dataclasses.replace(cfg, kv_quant="m2xfp")
    packed = prequantize_params(params, qcfg)
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(0, qcfg.vocab_size, n)))
               for n in (4, 6, 3)]
    eng = ServeEngine(packed, qcfg, n_slots=2, max_len=32)
    outs = eng.generate(prompts, max_new_tokens=3)
    for prompt, got in zip(prompts, outs):
        assert got == _serve_single(packed, qcfg, prompt, 3)


def test_slot_reuse_does_not_leak_state(packed_model):
    """A request admitted into a reused slot sees a clean page: serving the
    same prompt twice (before/after other traffic) yields identical
    output."""
    cfg, _, packed = packed_model
    rng = np.random.default_rng(5)
    probe = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    filler = [list(map(int, rng.integers(0, cfg.vocab_size, 6)))
              for _ in range(3)]
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    first = eng.generate([probe] + filler, max_new_tokens=4)[0]
    again = eng.generate([probe], max_new_tokens=4)[0]
    assert first == again


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_scheduler_admit_evict_invariants():
    sched = SlotScheduler(3)
    reqs = [sched.submit([1, 2], max_new_tokens=4) for _ in range(5)]
    sched.check()
    admitted = sched.admit(step=0)
    assert [r.rid for r in admitted] == [0, 1, 2]      # FIFO
    assert not sched.free and len(sched.queue) == 2
    sched.check()
    # evicting frees the slot; next admit reuses it for the oldest queued
    slot = reqs[1].slot
    sched.evict(slot, step=7)
    sched.check()
    assert reqs[1].state == "finished" and reqs[1].finish_step == 7
    nxt = sched.admit(step=8)
    assert [r.rid for r in nxt] == [3] and nxt[0].slot == slot
    sched.check()
    # draining everything returns all slots to free
    while sched.has_work:
        for s in list(sched.active):
            sched.evict(s)
        sched.admit()
        sched.check()
    assert sorted(sched.free) == [0, 1, 2]
    assert len(sched.finished) == 5


def test_scheduler_rejects_bad_requests():
    sched = SlotScheduler(1)
    with pytest.raises(ValueError):
        sched.submit([], max_new_tokens=2)
    with pytest.raises(ValueError):
        SlotScheduler(0)


def test_engine_rejects_over_capacity_prompt(packed_model):
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=1, max_len=8)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(list(range(6)), max_new_tokens=6)


def test_eos_stops_generation(packed_model):
    """A request whose sampler emits eos finishes early and frees the
    slot."""
    cfg, _, packed = packed_model

    def always_eos(logits):
        return np.full((logits.shape[0],), 42, np.int32)

    eng = ServeEngine(packed, cfg, n_slots=1, max_len=32,
                      sample_fn=always_eos)
    req = eng.submit([1, 2, 3], max_new_tokens=10, eos_id=42)
    eng.run()
    assert req.output == [42] and req.state == "finished"
    eng.scheduler.check()


# ---------------------------------------------------------------------------
# Stats / accounting
# ---------------------------------------------------------------------------

def test_run_returns_only_this_drain(packed_model):
    """A second submit/run cycle must not re-deliver earlier requests."""
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    r1 = eng.submit([1, 2, 3], max_new_tokens=2)
    first = eng.run()
    assert [r.rid for r in first] == [r1.rid]
    r2 = eng.submit([4, 5], max_new_tokens=2)
    second = eng.run()
    assert [r.rid for r in second] == [r2.rid]


def test_stats_token_accounting(packed_model):
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    prompts = [[1, 2, 3, 4], [5, 6]]
    eng.generate(prompts, max_new_tokens=3)
    s = eng.stats
    assert s.generated_tokens == 2 * 3
    # every active slot-step processed exactly one token
    assert s.prefill_tokens + s.generated_tokens == s.slot_steps
    assert 0 < s.occupancy <= 1
