"""Fault-tolerance tests: checkpoint integrity (CRC-32, truncation),
packed-stream validation/repair, poisoned-slot quarantine with survivor
bit-exactness, deadlines, backpressure, transient-step retries, and the
seeded chaos harness (repro.testing.faults). Injection tests carry the
``chaos`` marker."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError, leaf_crc32, restore_state, save_state,
)
from repro.core.codecs import (
    PackedTensor, validate_packed, validate_packed_tree,
)
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import (
    AdmissionError, EngineFailedError, GuardConfig, ServeEngine,
    SlotScheduler, StreamIntegrityError, load_packed_checkpoint,
    prequantize_params, save_packed_checkpoint, verify_packed_tree,
)
from repro.serve.guard import DEGRADED, FAILED, HEALTHY, EngineGuard
from repro.testing import (
    FaultInjector, FaultPlan, chaos_plan, corrupt_checkpoint_leaf,
    truncate_checkpoint,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="fault-test", family="dense", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=97,
                remat=False, quant="serve", kv_quant="m2xfp")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def packed_model():
    cfg = _cfg()
    params = init_params(KEY, cfg)
    return cfg, params, prequantize_params(params, cfg)


def _prompts(n, length=6):
    return [[(7 * i + j) % 97 for j in range(length)] for i in range(n)]


def _run(packed, cfg, plan=None, n=4, tokens=8, **engine_kw):
    eng = ServeEngine(packed, cfg, n_slots=4, max_len=32, prefill_chunk=4,
                      **engine_kw)
    reqs = [eng.submit(p, tokens) for p in _prompts(n)]
    if plan is not None:
        with FaultInjector(eng, plan):
            eng.run()
    else:
        eng.run()
    return eng, reqs


# ---------------------------------------------------------------------------
# Checkpoint integrity: CRC-32 + truncation
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_checkpoint_crc_catches_bit_flip(tmp_path):
    """One flipped bit anywhere in a checkpoint is caught on load, and the
    error names the damaged leaf."""
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
             "b": np.ones((8,), np.float32)}
    save_state(str(tmp_path), 0, state)
    bad = corrupt_checkpoint_leaf(str(tmp_path), seed=3)
    with pytest.raises(CheckpointCorruptError) as ei:
        restore_state(str(tmp_path), state)
    assert ei.value.leaf == bad
    assert bad in str(ei.value) and "CRC-32" in str(ei.value)
    # verification is opt-out for forensics
    restored, _ = restore_state(str(tmp_path), state, verify=False)
    assert not np.array_equal(np.asarray(restored[bad]), state[bad])


@pytest.mark.chaos
def test_checkpoint_truncation_actionable_error(tmp_path):
    state = {"w": np.arange(4096, dtype=np.float32)}
    save_state(str(tmp_path), 0, state)
    truncate_checkpoint(str(tmp_path), nbytes=100)
    with pytest.raises(CheckpointCorruptError) as ei:
        restore_state(str(tmp_path), state)
    assert "restore an older step" in str(ei.value)


def test_leaf_crc32_is_dtype_agnostic():
    """bf16 leaves hash identically whether seen as bfloat16 or as the raw
    void bytes the npz container stores."""
    import ml_dtypes
    a = np.arange(32, dtype=np.float32).astype(ml_dtypes.bfloat16)
    assert leaf_crc32(a) == leaf_crc32(a.view(np.dtype("V2")))


@pytest.mark.chaos
def test_packed_checkpoint_crc_on_load(packed_model, tmp_path):
    """Acceptance: a flipped byte in a packed-weight checkpoint is caught
    by load_packed_checkpoint, naming the leaf."""
    cfg, _, packed = packed_model
    save_packed_checkpoint(str(tmp_path), packed, cfg)
    bad = corrupt_checkpoint_leaf(str(tmp_path), seed=11)
    with pytest.raises(CheckpointCorruptError) as ei:
        load_packed_checkpoint(str(tmp_path), cfg)
    assert ei.value.leaf == bad


# ---------------------------------------------------------------------------
# Packed-stream validation + graceful degradation
# ---------------------------------------------------------------------------

def _first_packed_index(tree):
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, PackedTensor))
    return next(i for i, l in enumerate(leaves)
                if isinstance(l, PackedTensor))


def _poison_scale_leaf(tree, byte):
    """Return a copy of ``tree`` with one packed leaf's first scale byte
    overwritten."""
    is_p = lambda x: isinstance(x, PackedTensor)  # noqa: E731
    flat, tdef = jax.tree_util.tree_flatten(tree, is_leaf=is_p)
    i = _first_packed_index(tree)
    p = flat[i]
    streams = dict(p.streams)
    flat_idx = (0,) * streams["scales"].ndim
    streams["scales"] = streams["scales"].at[flat_idx].set(byte)
    flat[i] = PackedTensor(streams, p.shape, p.codec)
    return jax.tree_util.tree_unflatten(tdef, flat)


def test_validate_packed_flags_illegal_scale_bytes(packed_model):
    cfg, _, packed = packed_model
    report = validate_packed_tree(packed)
    assert report == {}, "freshly packed tree must validate clean"
    for byte in (0, 255):
        bad = _poison_scale_leaf(packed, byte)
        report = validate_packed_tree(bad)
        assert len(report) == 1
        (leaf, problems), = report.items()
        assert "scale byte" in problems[0]


def test_verify_packed_tree_requantize_repair(packed_model):
    """With source weights available, repair is an exact restore (the
    encoders are deterministic)."""
    cfg, params, packed = packed_model
    bad = _poison_scale_leaf(packed, 255)
    fixed, repairs = verify_packed_tree(bad, cfg=cfg, source_params=params)
    assert repairs and all(m == "requantize" for _, m in repairs)
    for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(fixed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_packed_tree_clamp_fallback(packed_model):
    """Without source weights, scale-byte damage degrades to a clamp —
    decodable (finite) streams instead of inf, flagged as a repair."""
    cfg, _, packed = packed_model
    bad = _poison_scale_leaf(packed, 255)
    fixed, repairs = verify_packed_tree(bad)
    assert repairs and all(m == "clamp" for _, m in repairs)
    assert validate_packed_tree(fixed) == {}
    with pytest.raises(StreamIntegrityError):
        verify_packed_tree(bad, repair=False)


def test_verify_packed_tree_intact_is_identity(packed_model):
    cfg, _, packed = packed_model
    out, repairs = verify_packed_tree(packed)
    assert out is packed and repairs == []


# ---------------------------------------------------------------------------
# Scheduler hardening: validation, backpressure, deadlines
# ---------------------------------------------------------------------------

def test_scheduler_submit_validation():
    s = SlotScheduler(2, max_prompt_len=8)
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit([], 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        s.submit([1], 0)
    with pytest.raises(ValueError, match="exceeds the cache page"):
        s.submit(list(range(9)), 4)
    with pytest.raises(ValueError, match="ttl_steps"):
        s.submit([1], 4, ttl_steps=0)
    s.check()


def test_scheduler_backpressure_sheds_with_reason():
    s = SlotScheduler(1, max_queue=2)
    s.submit([1], 1)
    s.submit([2], 1)
    with pytest.raises(AdmissionError) as ei:
        s.submit([3], 1)
    assert ei.value.reason == "queue_full"
    s.check()


def test_scheduler_expire_queued_and_running():
    s = SlotScheduler(1)
    a = s.submit([1, 2], 4, ttl_steps=3, step=0)   # will run
    b = s.submit([3], 4, ttl_steps=2, step=0)      # starves in queue
    s.admit(step=0)
    assert s.expire(1) == []
    out = s.expire(2)                              # b's deadline
    assert out == [b] and b.state == "expired"
    assert b.fail_reason == "deadline_queued"
    out = s.expire(3)                              # a's deadline, mid-run
    assert out == [a] and a.state == "expired"
    assert a.fail_reason == "deadline_running"
    assert s.free == [0]
    s.check()


@pytest.mark.chaos
def test_engine_backpressure_and_deadlines(packed_model):
    """Bounded queue sheds; per-request deadlines evict both queued and
    running requests; counters land in stats."""
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32, prefill_chunk=4,
                      max_queue=4, default_ttl_steps=3)
    reqs = [eng.submit(p, 8) for p in _prompts(4)]   # fills the queue
    with pytest.raises(AdmissionError):
        eng.submit([1, 2, 3], 8)
    assert eng.stats.shed == 1
    eng.run()
    assert eng.stats.expired > 0
    states = {r.state for r in reqs}
    assert "expired" in states
    assert eng.scheduler.expired and all(
        r.fail_reason.startswith("deadline") for r in eng.scheduler.expired)
    eng.scheduler.check()


# ---------------------------------------------------------------------------
# Poisoned-slot quarantine (the acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_quarantine_poisoned_slots_survivors_bit_identical(packed_model):
    """Under a bit flip in one slot's packed KV stream plus a NaN in
    another slot's logits, exactly those two requests are quarantined and
    every survivor's tokens are bit-identical to the fault-free run."""
    cfg, _, packed = packed_model
    _, clean_reqs = _run(packed, cfg)
    clean = [r.output for r in clean_reqs]

    plan = FaultPlan(seed=1, kv_poison_steps=((3, 1),),
                     nan_logit_steps=((4, 2),))
    eng, reqs = _run(packed, cfg, plan=plan)
    states = [r.state for r in reqs]
    assert states[1] == "quarantined" and states[2] == "quarantined"
    assert states[0] == "finished" and states[3] == "finished"
    assert reqs[0].output == clean[0]
    assert reqs[3].output == clean[3]
    assert eng.stats.quarantined == 2
    assert {r.fail_reason for r in eng.scheduler.quarantined} == \
        {"kv", "logits"}
    # containment worked: served through the faults, never FAILED
    assert eng.health in (HEALTHY, DEGRADED)
    eng.scheduler.check()


@pytest.mark.chaos
def test_quarantined_slot_is_reusable(packed_model):
    """A scrubbed slot serves later requests correctly — no poison and no
    stale state leaks to the next occupant. With every slot occupied, the
    quarantined slot frees first, so the follow-up request lands on it."""
    cfg, _, packed = packed_model
    plan = FaultPlan(seed=2, kv_poison_steps=((3, 0),))
    eng, reqs = _run(packed, cfg, plan=plan, n=4)
    assert reqs[0].state == "quarantined"
    # clean engine reference for the same prompt
    _, ref = _run(packed, cfg, n=4)
    out = eng.generate([_prompts(4)[0]], 8)
    assert out[0] == ref[0].output
    assert eng.stats.quarantined == 1            # no re-quarantine


# ---------------------------------------------------------------------------
# Transient failures, watchdog, health state machine
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_transient_step_failure_retried(packed_model):
    cfg, _, packed = packed_model
    plan = FaultPlan(seed=3, fail_steps=(2,))
    eng, reqs = _run(packed, cfg, plan=plan)
    assert all(r.state == "finished" for r in reqs)
    assert eng.guard.retries == 1
    # clean reference: the retried run loses no tokens
    _, ref = _run(packed, cfg)
    assert [r.output for r in reqs] == [r.output for r in ref]


@pytest.mark.chaos
def test_persistent_failure_fails_engine(packed_model):
    from repro.serve.guard import TransientStepError
    cfg, _, packed = packed_model
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32,
                      guard=GuardConfig(max_step_retries=1,
                                        retry_backoff_s=0.0))
    eng.submit(_prompts(1)[0], 4)

    def always_fail(*a, **k):
        raise TransientStepError("injected: persistent")

    eng._step = always_fail
    eng._prefill = always_fail
    with pytest.raises(EngineFailedError):
        eng.run()
    assert eng.health == FAILED
    with pytest.raises(EngineFailedError):       # refuses further work
        eng.step()
    with pytest.raises(EngineFailedError):
        eng.submit([1], 1)


def test_watchdog_and_recovery_state_machine():
    """Unit-level: a slow step trips the watchdog into DEGRADED; the
    configured streak of clean steps recovers to HEALTHY."""
    g = EngineGuard(GuardConfig(watchdog_s=0.1, recovery_steps=2))
    assert g.state == HEALTHY
    g.note_step(0.5)                  # trip
    assert g.state == DEGRADED and g.watchdog_trips == 1
    g.note_step(0.01)
    assert g.state == DEGRADED        # streak 1 of 2
    g.note_step(0.01)
    assert g.state == HEALTHY
    assert g.degraded_steps == 3


def test_quarantine_budget_exhaustion_fails():
    g = EngineGuard(GuardConfig(max_quarantines=1))
    g.record_quarantine("kv")
    assert g.state == DEGRADED
    g.record_quarantine("logits")
    assert g.state == FAILED
    with pytest.raises(EngineFailedError):
        g.check_alive()


def test_guard_off_is_available():
    """guard=False builds an engine with no guard machinery at all."""
    cfg = _cfg()
    params = init_params(KEY, cfg)
    packed = prequantize_params(params, cfg)
    eng = ServeEngine(packed, cfg, n_slots=2, max_len=32, guard=False)
    assert eng.guard is None and eng.health == HEALTHY
    assert eng.guard_summary() == {}


# ---------------------------------------------------------------------------
# Seeded chaos: everything at once
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.fuzz
def test_chaos_run_recovers(packed_model):
    """A seeded chaos plan (KV bit flip + NaN logits + transient failure)
    never FAILs the engine, and work still completes."""
    cfg, _, packed = packed_model
    plan = chaos_plan(seed=7, n_slots=4, first_step=2, horizon=12)
    eng = ServeEngine(packed, cfg, n_slots=4, max_len=32, prefill_chunk=4)
    reqs = [eng.submit(p, 6) for p in _prompts(8)]
    with FaultInjector(eng, plan) as inj:
        eng.run()
    assert eng.health != FAILED
    done = [r for r in reqs if r.state == "finished"]
    assert len(done) > 0
    assert len(done) + eng.stats.quarantined + eng.stats.expired == len(reqs)
    assert inj.fired, "plan never fired — dead harness"
    eng.scheduler.check()


@pytest.mark.chaos
def test_chaos_plan_is_deterministic():
    assert chaos_plan(5, 4) == chaos_plan(5, 4)
    assert chaos_plan(5, 4) != chaos_plan(6, 4)
