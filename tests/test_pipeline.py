"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.pipeline import pipeline_apply

        mesh = make_test_mesh((4, 2), ("pipe", "model"))
        n_stages, n_micro, mb, d = 4, 6, 8, 32
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * d ** -0.5,
                         dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((n_micro, mb, d)),
                        dtype=jnp.float32)

        def stage(w, h):
            return jnp.tanh(h @ w)

        out = pipeline_apply(stage, ws, x, mesh, n_stages)

        ref = x
        for i in range(n_stages):
            ref = jax.vmap(lambda h: stage(ws[i], h))(ref)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out
