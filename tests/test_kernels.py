"""Pallas kernel validation: shape/dtype sweeps against ref.py oracles
(interpret=True on CPU; identical code targets Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.layout import (
    interleave_pack, interleave_unpack, pack_w_mxfp4, pack_w_sgem,
    pack_x_elem_em,
)
from repro.core.m2xfp import quantize_act_m2xfp, quantize_weight_m2xfp

SHAPES = [(8, 64, 128), (16, 128, 128), (128, 512, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(m, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32)).astype(dtype)
    w = jnp.asarray((rng.standard_normal((k, n)) * 0.05).astype(np.float32))
    return x, w


def test_interleave_roundtrip():
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 16, (128, 64)), dtype=jnp.int32)
    assert jnp.array_equal(interleave_unpack(interleave_pack(c)), c)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_m2xfp_matmul_vs_ref(m, k, n, dtype):
    x, w = _data(m, k, n, dtype)
    wp = pack_w_sgem(w)
    out_k = ops.m2xfp_matmul(x, wp, block_m=min(m, 128),
                             block_n=min(n, 128), block_k=min(k, 256))
    out_r = ref.m2xfp_matmul_ref(x, wp)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES[:2])
def test_mxfp4_matmul_vs_ref(m, k, n):
    x, w = _data(m, k, n, jnp.bfloat16)
    wp = pack_w_mxfp4(w)
    out_k = ops.mxfp4_matmul(x, wp, block_m=min(m, 128),
                             block_n=min(n, 128), block_k=min(k, 256))
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(ref.mxfp4_matmul_ref(x, wp)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k", [(64, 64), (128, 512), (256, 1024)])
def test_quantize_kernel_bit_exact(m, k):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 3)
    got = ops.m2xfp_quantize(x, block_m=min(m, 128), block_k=min(k, 256))
    want = ref.m2xfp_quantize_ref(x.T)
    for key in ("codes", "scales", "meta"):
        assert jnp.array_equal(got[key], want[key]), key


@pytest.mark.parametrize("m,k,n", SHAPES[:2])
def test_qmatmul_vs_ref(m, k, n):
    x, w = _data(m, k, n, jnp.float32, seed=2)
    xp = pack_x_elem_em(x)
    wp = pack_w_sgem(w)
    out_k = ops.m2xfp_qmatmul(xp, wp, block_m=min(m, 128),
                              block_n=min(n, 128), block_k=min(k, 256))
    out_r = ref.m2xfp_qmatmul_ref(xp, wp)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


def test_kernel_decode_equals_core_fake_quant():
    """The full kernel pipeline implements exactly the core algorithm:
    quantize-kernel -> qmatmul == fake-quant(x) @ fake-quant(w)."""
    rng = np.random.default_rng(3)
    m, k, n = 64, 256, 128
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.1)
    xp = ops.m2xfp_quantize(x, block_m=64, block_k=256)
    wp = pack_w_sgem(w)
    out = ops.m2xfp_qmatmul(xp, wp, block_m=64, block_n=128, block_k=256)
    xq = quantize_act_m2xfp(x).astype(jnp.bfloat16)
    wq = quantize_weight_m2xfp(w.T).T.astype(jnp.bfloat16)
    want = jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_bitmath_matches_luts():
    """Kernel bit-arithmetic converters == core LUT converters on all codes."""
    from repro.kernels import bitmath
    from repro.core.dtypes import (
        fp4_code_to_value, fp6_code_to_value, FP4_MAG_VALUES, FP6_MAG_VALUES)
    c4 = jnp.arange(8)
    assert jnp.array_equal(bitmath.fp4_mag_from_code(c4),
                           fp4_code_to_value(c4))
    assert jnp.array_equal(bitmath.fp4_code_from_mag(FP4_MAG_VALUES), c4)
    c6 = jnp.arange(32)
    assert jnp.array_equal(bitmath.fp6_mag_from_code(c6),
                           fp6_code_to_value(c6))
    assert jnp.array_equal(bitmath.fp6_code_from_mag(FP6_MAG_VALUES), c6)
    # rtne parity on a dense sweep
    xs = jnp.linspace(-8, 8, 4097)
    from repro.core.dtypes import round_to_grid, FP4_E2M1, FP6_E2M3
    assert jnp.array_equal(bitmath.rtne_fp4(xs), round_to_grid(xs, FP4_E2M1))
    assert jnp.array_equal(bitmath.rtne_fp6(xs), round_to_grid(xs, FP6_E2M3))


# ---------------------------------------------------------------------------
# Conformance matrix: Pallas vs reference, bit-exactness domain
# ---------------------------------------------------------------------------
# Bit-exactness holds while kernel and reference reduce the contraction in
# the same order — empirically K <= 256 on this backend (XLA's dot starts
# partitioning the K panel around 512, and kernel split-K engages past
# block_k). Beyond that, conformance is a tight allclose (f32 accumulation
# reordering, last-ulp), not equality.

CONF_MS = [1, 3, 8, 9, 24, 100, 129]          # incl. non-multiples of 8/128
CONF_KNS = [(64, 128), (256, 128), (128, 256)]


@pytest.mark.parametrize("m", CONF_MS)
@pytest.mark.parametrize("k,n", CONF_KNS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_m2xfp_matmul_conformance_bitexact(m, k, n, dtype):
    """Adaptive-block launches are bit-equal to the XLA reference for every
    row count — the invariant the chunked-prefill serve path relies on
    (decode feeds B rows, prefill B*chunk rows, same results per row)."""
    x, w = _data(m, k, n, dtype, seed=m)
    wp = pack_w_sgem(w)
    out_k = ops.m2xfp_matmul(x, wp)            # block_m picked from M
    out_r = ref.m2xfp_matmul_ref(x, wp)
    assert out_k.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("k", [512, 1024])
@pytest.mark.parametrize("dtype", DTYPES)
def test_m2xfp_matmul_conformance_large_k(k, dtype):
    """Large K reorders the f32 accumulation (XLA panel partitioning at
    K=512, kernel split-K at K > block_k): tightly allclose, not
    bit-equal."""
    m, n = 16, 128
    x, w = _data(m, k, n, dtype, seed=9)
    wp = pack_w_sgem(w)
    out_k = ops.m2xfp_matmul(x, wp, block_k=512)
    out_r = ref.m2xfp_matmul_ref(x, wp)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-6, atol=2e-6)


def test_serve_block_m_policy():
    assert [ops.serve_block_m(m) for m in (1, 8, 9, 24, 100, 128, 500)] \
        == [8, 8, 16, 24, 104, 128, 128]


@pytest.mark.parametrize("dtype", DTYPES)
def test_serve_matmul_backend_conformance(monkeypatch, dtype):
    """Both REPRO_SERVE_KERNEL settings produce bit-identical serve GEMM
    results (the Pallas kernel vs its pure-XLA mirror), for decode-like
    (M=2) and prefill-like (M=2*8 chunk rows) launches."""
    from repro.models.quant import pack_serving_weight, quantized_matmul
    rng = np.random.default_rng(11)
    k, n = 128, 128
    w = jnp.asarray((rng.standard_normal((k, n)) * 0.05).astype(np.float32))
    wp = pack_serving_weight(w)
    for rows in (2, 16):
        x = jnp.asarray(rng.standard_normal((rows, k)).astype(np.float32)
                        ).astype(dtype)
        by_mode = {}
        for mode in ("xla", "pallas"):
            monkeypatch.setenv("REPRO_SERVE_KERNEL", mode)
            by_mode[mode] = np.asarray(
                quantized_matmul(x, wp, "serve").astype(jnp.float32))
        np.testing.assert_array_equal(by_mode["xla"], by_mode["pallas"])


def test_serve_matmul_untileable_shape_falls_back(monkeypatch):
    """REPRO_SERVE_KERNEL=pallas with N not a multiple of 128 must fall
    back to the XLA mirror (Mosaic lane constraint), not crash."""
    from repro.models.quant import pack_serving_weight, quantized_matmul
    rng = np.random.default_rng(13)
    k, n = 64, 96
    w = jnp.asarray((rng.standard_normal((k, n)) * 0.05).astype(np.float32))
    wp = pack_serving_weight(w)
    x = jnp.asarray(rng.standard_normal((4, k)).astype(np.float32))
    monkeypatch.setenv("REPRO_SERVE_KERNEL", "pallas")
    got = np.asarray(quantized_matmul(x, wp, "serve").astype(jnp.float32))
    monkeypatch.setenv("REPRO_SERVE_KERNEL", "xla")
    want = np.asarray(quantized_matmul(x, wp, "serve").astype(jnp.float32))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("window,softcap", [(1 << 30, None), (48, None),
                                            (1 << 30, 8.0)])
def test_flash_attention_kernel_vs_dense(window, softcap):
    from repro.kernels.flash_attention import flash_attention_kernel
    rng = np.random.default_rng(7)
    BH, S, HD = 3, 128, 64
    q = jnp.asarray(rng.standard_normal((BH, S, HD)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((BH, S, HD)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((BH, S, HD)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S), (BH, S)).astype(jnp.int32)
    s = jnp.einsum("bsd,btd->bst",
                   q.astype(jnp.bfloat16).astype(jnp.float32),
                   k.astype(jnp.bfloat16).astype(jnp.float32)) * HD ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = (pos[:, :, None] >= pos[:, None, :]) & \
        (pos[:, :, None] - pos[:, None, :] < window)
    s = jnp.where(mask, s, -2e38)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bst,btd->bsd",
                     p.astype(jnp.bfloat16).astype(jnp.float32), v)
    got = flash_attention_kernel(q, k, v, pos, pos, softcap=softcap,
                                 window=window, bq=32, bk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)
