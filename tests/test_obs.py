"""Observability layer: registry math, span tracing, quantization health,
engine integration, and the REPRO_OBS=off bit-identity guarantee.

The bit-identity test is the contract the whole layer rests on: with
REPRO_OBS unset the serve path must produce exactly the tokens an
uninstrumented build produces (no probe may perturb the traced graphs).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import ServeEngine, prequantize_params, tree_nbytes
from repro.serve.engine import ServeStats

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    """Every test starts with observability off and empty buffers."""
    monkeypatch.delenv("REPRO_OBS", raising=False)
    monkeypatch.delenv("REPRO_OBS_DIR", raising=False)
    obs.reset()
    yield
    obs.reset()


def tiny_cfg(**kw):
    kw.setdefault("quant", "serve")
    kw.setdefault("kv_quant", "m2xfp")
    return ModelConfig(name="obs-test", family="dense", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                       vocab_size=256, remat=False, **kw)


def tiny_packed(cfg):
    return prequantize_params(init_params(jax.random.PRNGKey(0), cfg), cfg)


PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8]]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_monotonicity():
    c = obs.counter("t_total", "help text")
    c.inc()
    c.inc(2.5, site="a")
    c.inc(site="a")
    assert c.value() == 1.0
    assert c.value(site="a") == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_add():
    g = obs.gauge("t_gauge")
    g.set(2.0, k="x")
    g.add(0.5, k="x")
    assert g.value(k="x") == 2.5
    assert g.value() == 0.0                    # unseen label set


def test_histogram_cumulative_buckets():
    h = obs.histogram("t_hist", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0, 0.1):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"1.0": 2, "10.0": 3, "+Inf": 4}
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(55.6)


def test_registry_kind_mismatch():
    obs.counter("t_same")
    with pytest.raises(TypeError):
        obs.gauge("t_same")


def test_prometheus_exposition_format():
    obs.counter("t_req_total", "requests").inc(3, route="/v1")
    h = obs.histogram("t_lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05, phase="p")
    h.observe(0.5, phase="p")
    text = obs.registry().render_prometheus()
    assert "# HELP t_req_total requests" in text
    assert "# TYPE t_req_total counter" in text
    assert 't_req_total{route="/v1"} 3.0' in text
    assert 't_lat_seconds_bucket{phase="p",le="0.1"} 1' in text
    assert 't_lat_seconds_bucket{phase="p",le="+Inf"} 2' in text
    assert 't_lat_seconds_count{phase="p"} 2' in text


def test_jsonl_dump_appends(tmp_path):
    obs.counter("t_a").inc()
    path = str(tmp_path / "m.jsonl")
    n1 = obs.registry().dump_jsonl(path)
    obs.counter("t_a").inc()
    n2 = obs.registry().dump_jsonl(path)
    assert n1 == n2 == 1
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) == 2
    assert recs[-1]["value"] == 2.0            # last record wins semantics


def test_enabled_modes(monkeypatch):
    assert not obs.enabled()
    monkeypatch.setenv("REPRO_OBS", "0")
    assert not obs.enabled("trace")
    monkeypatch.setenv("REPRO_OBS", "1")
    assert all(obs.enabled(p) for p in obs.PILLARS)
    monkeypatch.setenv("REPRO_OBS", "metrics,trace")
    assert obs.enabled("metrics") and obs.enabled("trace")
    assert not obs.enabled("health")
    monkeypatch.setenv("REPRO_OBS", "metrcs")
    with pytest.raises(ValueError, match="unknown pillar"):
        obs.enabled()


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def test_spans_disabled_record_nothing():
    with obs.span("t.outer"):
        pass
    obs.instant("t.mark")
    assert obs.tracer().events() == []


def test_span_nesting_and_export(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS", "trace")
    with obs.span("t.outer", cat="t", job=1):
        with obs.span("t.inner", cat="t"):
            pass
    evs = obs.tracer().events()
    assert [e["name"] for e in evs] == ["t.inner", "t.outer"]
    inner, outer = evs
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert inner["tid"] == outer["tid"] and outer["ph"] == "X"
    assert outer["args"] == {"job": 1}

    path = str(tmp_path / "trace.json")
    n = obs.export_chrome_trace(path)
    assert n == 2
    doc = json.load(open(path))
    names = [e["name"] for e in doc["traceEvents"]]
    assert "process_name" in names and "t.outer" in names


# ---------------------------------------------------------------------------
# quantization health
# ---------------------------------------------------------------------------

def test_weight_tree_health_report(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "health")
    from repro.models.quant import pack_serving_weight
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32) * 0.1)
    report = obs.quant_health.weight_tree_health(
        {"layer0": pack_serving_weight(w)})
    st = report["layer0"]
    assert st["elems"] == w.size
    assert 0.0 <= st["clip_rate"] <= 1.0
    # each meta byte packs four 2-bit subgroup codes
    assert sum(st["meta_hist"]) == 4 * st["groups"]
    assert st["reencode_drift"] < 1e-3           # Sg-EM ~idempotent
    g = obs.gauge("repro_quant_clip_rate")
    assert g.value(layer="layer0", codec="m2xfp",
                   kind="weight") == st["clip_rate"]


def test_act_reencode_drift_small():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((8, 64)).astype(np.float32)
    assert obs.quant_health.act_reencode_drift(x) < 1e-3


def test_e8m0_bounds_constants():
    # repro.core.scaling clamps exponents to [-126, 127] -> bytes [1, 254]
    assert obs.quant_health.E8M0_BYTE_LOW == 1
    assert obs.quant_health.E8M0_BYTE_HIGH == 254


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_engine_emits_metrics_and_trace(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS", "1")
    cfg = tiny_cfg()
    eng = ServeEngine(tiny_packed(cfg), cfg, n_slots=2, max_len=32,
                      prefill_chunk=4)
    outs = eng.generate(PROMPTS, max_new_tokens=4)
    jax.effects_barrier()              # flush debug.callback health drains
    assert [len(o) for o in outs] == [4, 4]

    text = obs.registry().render_prometheus()
    # acceptance: TTFT + step-latency histograms in the exposition
    assert "repro_serve_step_latency_seconds_bucket" in text
    assert "repro_serve_ttft_steps_bucket" in text
    assert "repro_serve_steps_total" in text
    assert "repro_serve_occupancy" in text
    # acceptance: per-layer clip rate + online site health
    assert ('repro_quant_clip_rate{codec="m2xfp",kind="online",'
            'site="serve_gemm"}' in text)
    assert ('repro_quant_clip_rate{codec="m2xfp",kind="online",'
            'site="kv_encode"}' in text)
    assert 'kind="weight"' in text
    assert "repro_quant_reencode_drift" in text
    assert "repro_quant_meta_total" in text

    # acceptance: nested spans step -> phase -> kernel dispatch
    evs = obs.tracer().events()
    byname = {}
    for e in evs:
        byname.setdefault(e["name"], []).append(e)
    for required in ("serve.run", "serve.step", "serve.plan",
                     "serve.kernel.dispatch", "serve.weight_health",
                     "serve.sample"):
        assert required in byname, f"missing span {required}"
    assert ("serve.phase.decode" in byname or
            "serve.phase.prefill" in byname)

    def contains(outer, inner):
        return (outer["ts"] <= inner["ts"] + 1e-6 and
                inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
                + 1e-6 and outer["tid"] == inner["tid"])

    disp = byname["serve.kernel.dispatch"][0]
    phases = (byname.get("serve.phase.decode", []) +
              byname.get("serve.phase.prefill", []))
    phase = next(p for p in phases if contains(p, disp))
    step = next(s for s in byname["serve.step"] if contains(s, phase))
    assert contains(step, phase) and contains(phase, disp)

    # the trace file is a loadable Chrome trace
    path = str(tmp_path / "trace.json")
    obs.export_chrome_trace(path)
    doc = json.load(open(path))
    assert any(e.get("ph") == "X" for e in doc["traceEvents"])


@pytest.mark.smoke
def test_obs_off_bit_identical_tokens(monkeypatch):
    """Tier-1 acceptance: REPRO_OBS unset leaves serve output bit-identical
    to a REPRO_OBS=1 run (instrumentation never perturbs the math)."""
    cfg = tiny_cfg()
    packed = tiny_packed(cfg)

    monkeypatch.delenv("REPRO_OBS", raising=False)
    eng_off = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    out_off = eng_off.generate(PROMPTS, max_new_tokens=6)

    monkeypatch.setenv("REPRO_OBS", "1")
    obs.reset()
    eng_on = ServeEngine(packed, cfg, n_slots=2, max_len=32)
    out_on = eng_on.generate(PROMPTS, max_new_tokens=6)
    jax.effects_barrier()

    assert out_off == out_on
    assert "repro_serve_steps_total" in obs.registry().render_prometheus()


def test_obs_off_records_nothing():
    cfg = tiny_cfg()
    eng = ServeEngine(tiny_packed(cfg), cfg, n_slots=2, max_len=32)
    eng.generate(PROMPTS, max_new_tokens=2)
    assert obs.registry().render_prometheus() == ""
    assert obs.tracer().events() == []


def test_autodump_writes_obs_dir(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS", "1")
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path / "dump"))
    cfg = tiny_cfg(kv_quant="none")
    eng = ServeEngine(tiny_packed(cfg), cfg, n_slots=2, max_len=32)
    eng.generate(PROMPTS, max_new_tokens=2)
    assert (tmp_path / "dump" / "metrics.jsonl").exists()
    assert (tmp_path / "dump" / "trace.json").exists()


# ---------------------------------------------------------------------------
# satellites: ServeStats.to_dict, tree_nbytes, _env_int, obs_report
# ---------------------------------------------------------------------------

def test_servestats_to_dict():
    s = ServeStats(n_slots=4, steps=10, decode_steps=8, prefill_steps=2,
                   slot_steps=30, prefill_tokens=40, generated_tokens=20,
                   wall_s=2.0, prefill_wall_s=0.5, decode_wall_s=1.5)
    d = s.to_dict()
    assert d["steps"] == 10 and d["n_slots"] == 4
    assert d["tokens_per_sec"] == pytest.approx(30.0)
    assert d["prefill_tokens_per_sec"] == pytest.approx(80.0)
    assert d["decode_tokens_per_sec"] == pytest.approx(20.0 / 1.5)
    assert d["occupancy"] == pytest.approx(0.75)
    json.dumps(d)                                   # plain scalars only
    assert ServeStats().to_dict()["tokens_per_sec"] == 0.0


def test_tree_nbytes_packed_checkpoint():
    """Packed trees count their u8 streams exactly (satellite: packed-u8
    checkpoints)."""
    cfg = tiny_cfg()
    dense = init_params(jax.random.PRNGKey(0), cfg)
    packed = tiny_packed(cfg)
    expect = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(packed)
                 if hasattr(x, "dtype"))
    assert tree_nbytes(packed) == expect
    assert 0 < tree_nbytes(packed) < tree_nbytes(dense)

    from repro.models.quant import pack_serving_weight
    w = jnp.zeros((64, 16), jnp.float32)
    pw = pack_serving_weight(w)
    # codes (K/2, N) + scales (K/32, N) + meta (K/32, N), all u8
    assert tree_nbytes(pw) == 32 * 16 + 2 * 16 + 2 * 16
    assert {np.dtype(x.dtype) for x in jax.tree.leaves(pw)} == {
        np.dtype(np.uint8)}


def test_tree_nbytes_mixed_dtype_cache_tree():
    tree = {
        "f32": jnp.zeros((4, 4), jnp.float32),        # 64
        "bf16": jnp.zeros((8,), jnp.bfloat16),        # 16
        "i32": np.zeros((3,), np.int32),              # 12
        "u8": np.zeros((5,), np.uint8),               # 5
        "plain": 7,                                   # no dtype: skipped
    }
    assert tree_nbytes(tree) == 64 + 16 + 12 + 5

    from repro.models.model import init_caches
    caches = init_caches(tiny_cfg(), 2, 32, per_slot=True)
    expect = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)
                 if hasattr(x, "dtype"))
    assert tree_nbytes(caches) == expect > 0
    dtypes = {np.dtype(x.dtype) for x in jax.tree.leaves(caches)}
    assert len(dtypes) > 1                            # genuinely mixed


def test_env_int_validation(monkeypatch):
    from repro.models.attention import _env_int
    monkeypatch.delenv("T_OBS_X", raising=False)
    assert _env_int("T_OBS_X", 7) == 7
    monkeypatch.setenv("T_OBS_X", "3")
    assert _env_int("T_OBS_X", 7) == 3
    monkeypatch.setenv("T_OBS_X", "0")
    with pytest.raises(ValueError, match="must be >= 1"):
        _env_int("T_OBS_X", 7)
    monkeypatch.setenv("T_OBS_X", "-2")
    with pytest.raises(ValueError, match="must be >= 1"):
        _env_int("T_OBS_X", 7)
    monkeypatch.setenv("T_OBS_X", "banana")
    with pytest.raises(ValueError, match="not an integer"):
        _env_int("T_OBS_X", 7)
    monkeypatch.setenv("T_OBS_X", "4")
    assert _env_int("T_OBS_X", 7, minimum=4) == 4


def test_obs_report_renders_dump(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OBS", "1")
    obs.counter("repro_demo_total", "demo").inc(5, site="x")
    obs.histogram("repro_demo_seconds", "demo",
                  buckets=(0.1, 1.0)).observe(0.5)
    obs.gauge("repro_quant_clip_rate", "").set(
        0.25, layer="l0", kind="weight")
    with obs.span("demo.work", cat="demo"):
        pass
    d = str(tmp_path / "dump")
    obs.dump(d)

    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "obs_report.py"), d],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout
    assert "repro_demo_total{site=x} = 5" in out
    assert "count=1" in out and "p50=" in out
    assert "top clip-rate layers" in out and "l0" in out
    assert "demo.work" in out
