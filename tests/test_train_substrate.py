"""Optimizer, data pipeline, checkpointing, compression, straggler tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_state, \
    save_state
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.straggler import StragglerMonitor
from repro.train.compression import CompressionConfig, compress_decompress, \
    init_error_feedback
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, \
    clip_by_global_norm, global_norm, warmup_cosine


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      clip_norm=1e9, warmup_steps=0, total_steps=10)
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal((4, 8)).astype(np.float32)
    g = rng.standard_normal((4, 8)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    opt = adamw_init(params)
    sched = lambda s: jnp.float32(cfg.lr)
    new_p, new_opt, _ = adamw_update(params, {"w": jnp.asarray(g)}, opt,
                                     cfg, sched)
    # numpy reference, step 1
    m = 0.1 * g
    v = 0.01 * g * g
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    ref = p0 - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * p0)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(90)) < 1e-4


def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    s = warmup_cosine(cfg)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) < float(s(jnp.int32(50)))


def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="tiny", family="dense", n_layers=4, d_model=128,
                       n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=256,
                       remat=False)


def test_training_loss_decreases():
    """End-to-end: tiny LM on the synthetic motif stream learns."""
    from repro.models.model import init_params, loss_fn
    cfg = _tiny_cfg()
    # recipe verified to cross the motif-copying phase transition ~step 200
    data = SyntheticLM(DataConfig(batch=16, seq=128, vocab=256, seed=7,
                                  motif_len=12, noise=0.05))
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=300,
                       weight_decay=0.01)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, loss

    losses = []
    for i in range(300):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5]), losses[::30]


def test_microbatch_grads_match_full_batch():
    from repro.models.model import init_params
    from repro.train.trainer import _grads_and_loss
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (8, 64)), jnp.int32)}
    l1, g1 = jax.jit(lambda p, b: _grads_and_loss(p, cfg, b, 1))(params, batch)
    l4, g4 = jax.jit(lambda p, b: _grads_and_loss(p, cfg, b, 4))(params, batch)
    assert abs(float(l1) - float(l4)) < 5e-3
    rel = [float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
           for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4))]
    assert max(rel) < 0.05, max(rel)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_host_sharded():
    base = dict(batch=8, seq=32, vocab=128, seed=3)
    full = SyntheticLM(DataConfig(**base))
    b0 = full.batch_at(5)
    b0b = full.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    # two hosts partition the global batch exactly
    h0 = SyntheticLM(DataConfig(**base, host_id=0, num_hosts=2)).batch_at(5)
    h1 = SyntheticLM(DataConfig(**base, host_id=1, num_hosts=2)).batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b0["tokens"])


def test_prefetcher_orders_batches():
    src = SyntheticLM(DataConfig(batch=2, seq=16, vocab=64, seed=0))
    pf = Prefetcher(src, start_step=3, depth=2)
    try:
        s0, b0 = next(pf)
        s1, b1 = next(pf)
        assert (s0, s1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], src.batch_at(3)["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                        dtype=jnp.float32)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    st = _state()
    save_state(str(tmp_path), 10, st, extra={"data_step": 10})
    out, extra = restore_state(str(tmp_path), st)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert extra["data_step"] == 10


def test_checkpoint_retention_and_latest(tmp_path):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save_state(str(tmp_path), s, st, keep=2)
    assert latest_step(str(tmp_path)) == 5
    from repro.checkpoint.checkpoint import all_steps
    assert all_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_ignores_incomplete(tmp_path):
    st = _state()
    save_state(str(tmp_path), 1, st)
    # simulate a crash mid-write: tmp dir + manifest-less dir
    os.makedirs(tmp_path / "step_0000000002.tmp")
    os.makedirs(tmp_path / "step_0000000003")
    assert latest_step(str(tmp_path)) == 1
    out, _ = restore_state(str(tmp_path), st)
    assert int(out["opt"]["step"]) == 7


def test_checkpoint_manager_async_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2, keep=2)
    st = _state()
    assert not mgr.maybe_save(1, st)
    assert mgr.maybe_save(2, st, extra={"data_step": 2})
    mgr.wait()
    got, extra, step = mgr.resume(st)
    assert step == 2 and extra["data_step"] == 2


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    """With error feedback, the *cumulative* compressed sum tracks the
    cumulative true sum (residual stays bounded)."""
    cfg = CompressionConfig(enabled=True, int8=True, topk_density=0.25)
    rng = np.random.default_rng(0)
    g_true = jnp.zeros((256,))
    g_sent = jnp.zeros((256,))
    err = jnp.zeros((256,))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        sent, err = compress_decompress(g, err, cfg)
        g_true = g_true + g
        g_sent = g_sent + sent
    resid = float(jnp.linalg.norm(g_true - g_sent))
    assert resid == pytest.approx(float(jnp.linalg.norm(err)), rel=1e-4)
    assert resid < 0.15 * float(jnp.linalg.norm(g_true)) + 5.0


def test_int8_quant_bounded_error():
    cfg = CompressionConfig(enabled=True, int8=True, topk_density=1.0)
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    err0 = jnp.zeros((512,))
    deq, err = compress_decompress(g, err0, cfg)
    amax = float(jnp.max(jnp.abs(g)))
    assert float(jnp.max(jnp.abs(deq - g))) <= amax / 127.0 * 0.51 + 1e-6


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_sustained_slowness():
    events = []
    mon = StragglerMonitor(threshold=2.0, patience=2,
                           on_straggle=lambda s, dt: events.append(s))
    class FakeTime:
        t = 0.0
    times = [0.1] * 5 + [0.5, 0.5] + [0.1] * 3
    import repro.distributed.straggler as sg
    orig = sg.time.monotonic
    seq = iter(np.cumsum([0] + [t for t in times for _ in (0, 1)][:len(times) * 2]))
    try:
        vals = []
        acc = 0.0
        for t in times:
            vals += [acc, acc + t]
            acc += t
        it = iter(vals)
        sg.time.monotonic = lambda: next(it)
        for i in range(len(times)):
            mon.step_start()
            mon.step_end(i)
    finally:
        sg.time.monotonic = orig
    assert events, "sustained straggler not flagged"
