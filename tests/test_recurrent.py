"""Recurrence correctness: chunked-parallel forms == sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import mamba2 as mb
from repro.models import xlstm as xl

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="ssm", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=0, vocab_size=128, ssm_state=16,
                ssm_head_dim=16)
    base.update(kw)
    return ModelConfig(**base)


def test_mamba2_forward_equals_decode():
    cfg = _cfg()
    p = mb.init_mamba2(KEY, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 256, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    y, state = jax.jit(lambda p, x: mb.mamba2_forward(p, x, cfg))(p, x)
    cache = mb.init_mamba2_cache(cfg, 2)
    step = jax.jit(lambda p, x, c: mb.mamba2_decode(p, x, cfg, c))
    ys = []
    for t in range(256):
        yt, cache = step(p, x[:, t:t + 1], cache)
        ys.append(yt)
    yseq = jnp.concatenate(ys, axis=1)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yseq.astype(jnp.float32))))
    assert err < 0.05, err
    assert float(jnp.max(jnp.abs(state["ssm"] - cache["ssm"]))) < 1e-3
    np.testing.assert_allclose(np.asarray(state["conv"]),
                               np.asarray(cache["conv"]), atol=1e-5)


def test_mlstm_chunkwise_equals_sequential():
    rng = np.random.default_rng(1)
    B, S, H, P = 2, 256, 3, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32)
                    ) * P ** -0.5
    v = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    logi = jnp.asarray(rng.standard_normal((B, S, H)).astype(np.float32))
    logf = jnp.asarray(np.log(1 / (1 + np.exp(
        -rng.standard_normal((B, S, H)) - 2))).astype(np.float32))

    def naive():
        C = jnp.zeros((B, H, P, P))
        n = jnp.zeros((B, H, P))
        m = jnp.full((B, H), -1e30)
        hs = []
        for t in range(S):
            m_new = jnp.maximum(logf[:, t] + m, logi[:, t])
            wf = jnp.exp(logf[:, t] + m - m_new)
            wi = jnp.exp(logi[:, t] - m_new)
            C = C * wf[..., None, None] + wi[..., None, None] * jnp.einsum(
                "bhp,bhq->bhpq", k[:, t], v[:, t])
            n = n * wf[..., None] + wi[..., None] * k[:, t]
            m = m_new
            num = jnp.einsum("bhp,bhpq->bhq", q[:, t], C)
            den = jnp.maximum(
                jnp.abs(jnp.einsum("bhp,bhp->bh", q[:, t], n)),
                jnp.exp(-m))
            hs.append(num / den[..., None])
        return jnp.stack(hs, axis=1), C, n, m

    h_ref, C_ref, n_ref, m_ref = naive()
    h_ck, st = xl._mlstm_cell_chunkwise(q, k, v, logi, logf)
    assert float(jnp.max(jnp.abs(h_ck - h_ref))) < 1e-3
    assert float(jnp.max(jnp.abs(st["C"] - C_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(st["m"] - m_ref))) < 1e-4


def test_mlstm_block_forward_equals_decode():
    cfg = _cfg(n_heads=2)
    p = xl.init_mlstm(KEY, cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 64, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    y, _ = jax.jit(lambda p, x: xl.mlstm_forward(p, x, cfg))(p, x)
    cache = xl.init_mlstm_cache(cfg, 2)
    step = jax.jit(lambda p, x, c: xl.mlstm_decode(p, x, cfg, c))
    ys = []
    for t in range(64):
        yt, cache = step(p, x[:, t:t + 1], cache)
        ys.append(yt)
    yseq = jnp.concatenate(ys, axis=1)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yseq.astype(jnp.float32))))
    assert err < 0.08, err


def test_slstm_block_forward_equals_decode():
    cfg = _cfg(n_heads=4)
    p = xl.init_slstm(KEY, cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 48, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    y, _ = jax.jit(lambda p, x: xl.slstm_forward(p, x, cfg))(p, x)
    cache = xl.init_slstm_cache(cfg, 2)
    step = jax.jit(lambda p, x, c: xl.slstm_decode(p, x, cfg, c))
    ys = []
    for t in range(48):
        yt, cache = step(p, x[:, t:t + 1], cache)
        ys.append(yt)
    yseq = jnp.concatenate(ys, axis=1)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yseq.astype(jnp.float32))))
    assert err < 0.08, err
