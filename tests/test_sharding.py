"""Sharding-rule unit tests + multi-device integration via subprocess
(device count must be set before jax init, so CPU mesh tests fork)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import (
    DEFAULT_RULES, infer_logical_axes, logical_to_spec,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_infer_logical_axes_names():
    assert infer_logical_axes(("layers", "attn", "wq"), (4, 64, 128)) == \
        (None, "fsdp", "q_dim")
    assert infer_logical_axes(("layers", "ffn", "down"), (4, 128, 64)) == \
        (None, "mlp", "fsdp")
    assert infer_logical_axes(("layers", "ffn", "gate"), (4, 8, 64, 128)) \
        == (None, "expert", "fsdp", "expert_mlp")
    assert infer_logical_axes(("mlstm", "wq"), (4, 2, 16, 16)) == \
        (None, "heads", None, None)
    assert infer_logical_axes(("embed",), (1000, 64)) == ("vocab", "fsdp")
    assert infer_logical_axes(("final_norm",), (64,)) == (None,)


def _run_sub(code: str) -> dict:
    """Run code under 8 fake devices; it must print one JSON line."""
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_train_step_runs_sharded():
    """Tiny model trains on a 2x4 (data, model) mesh; loss finite; params
    actually sharded (per-device buffer < full size)."""
    r = _run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.models.config import ModelConfig
        from repro.train.trainer import (
            make_train_state, make_train_step, train_state_shardings,
            batch_sharding)
        from repro.train.optimizer import AdamWConfig
        from repro.distributed.sharding import use_sharding
        import numpy as np

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                          head_dim=16, remat=False)
        mesh = make_test_mesh((2, 4), ("data", "model"))
        with use_sharding(mesh):
            state = make_train_state(jax.random.PRNGKey(0), cfg)
            sh = train_state_shardings(state, mesh)
            state = jax.device_put(state, sh)
            step = make_train_step(cfg, AdamWConfig(lr=1e-3))
            rng = np.random.default_rng(0)
            batch = {
              "tokens": jax.device_put(
                  jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
                  batch_sharding(mesh)),
              "labels": jax.device_put(
                  jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
                  batch_sharding(mesh)),
            }
            fn = jax.jit(step, in_shardings=(sh, None),
                         out_shardings=(sh, None))
            state2, metrics = fn(state, batch)
            state3, metrics2 = fn(state2, batch)
        w = state3["params"]["layers"]["ffn"]["gate"]
        shard_frac = w.addressable_shards[0].data.size / w.size
        print(json.dumps({
            "loss1": float(metrics["loss"]), "loss2": float(metrics2["loss"]),
            "shard_frac": shard_frac}))
    """)
    assert r["loss2"] < r["loss1"] + 0.1
    assert r["shard_frac"] <= 0.25 + 1e-6    # sharded over >= 4 devices


@pytest.mark.slow
def test_compressed_psum_cross_pod():
    """shard_map over 'pod' with int8+topk compressed all-reduce: the pods
    end with identical parameters; result tracks the uncompressed mean."""
    r = _run_sub("""
        from repro.launch.mesh import make_test_mesh
        from repro.train.compression import CompressionConfig, compressed_psum
        from jax.sharding import PartitionSpec as P
        import numpy as np

        mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = CompressionConfig(enabled=True, int8=True, topk_density=1.0)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
        err = jnp.zeros_like(g)

        def body(g, err):
            red, new_err = compressed_psum({"g": g}, {"g": err}, cfg,
                                           "pod", 2)
            return red["g"], new_err["g"]

        f = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod")), axis_names={"pod"},
            check_vma=False))
        red, new_err = f(g, err)
        true_mean = jnp.mean(g.reshape(2, 1, 64), axis=0)
        # each pod row holds the same reduced value
        a = red[0]; b = red[1]
        print(json.dumps({
            "pods_equal": bool(jnp.allclose(a, b)),
            "err_vs_true": float(jnp.max(jnp.abs(a - true_mean[0])))}))
    """)
    assert r["pods_equal"]
    assert r["err_vs_true"] < 0.02


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """The dry-run machinery works end-to-end on an in-test 2x4 mesh."""
    r = _run_sub("""
        import repro.launch.dryrun as dr
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((2, 4), ("data", "model"))
        lowered, meta = dr.build_lowered("xlstm-125m", "decode_32k", mesh)
        compiled = lowered.compile()
        from repro.analysis.hlo import analyze_hlo
        a = analyze_hlo(compiled.as_text())
        print(json.dumps({"flops": a.flops > 0,
                          "trips": len(a.loop_trips) > 0}))
    """)
    assert r["flops"] and r["trips"]
