"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    FP4_E2M1, FP6_E2M3, quantize_act_m2xfp, quantize_mxfp4,
    quantize_weight_m2xfp, round_to_grid, shared_scale_exponent,
)
from repro.core.m2xfp import encode_act_m2xfp, decode_act_m2xfp
from repro.core.packing import (
    pack_meta2, pack_nibbles, unpack_meta2, unpack_nibbles,
)
from repro.models.kvquant import kv_decode, kv_encode

_f32 = hnp.arrays(
    np.float32, st.tuples(st.integers(1, 4), st.just(64)),
    elements=st.floats(-1e4, 1e4, width=32, allow_nan=False,
                       allow_infinity=False))

# full finite f32 range incl. subnormals and +-0 — what a KV page may see
_f32_extreme = hnp.arrays(
    np.float32, st.tuples(st.integers(1, 3), st.just(64)),
    elements=st.floats(width=32, allow_nan=False, allow_infinity=False,
                       allow_subnormal=True))

_u8 = hnp.arrays(np.uint8, st.tuples(st.integers(1, 4), st.just(32)),
                 elements=st.integers(0, 255))


@settings(max_examples=30, deadline=None)
@given(_f32)
def test_quantize_idempotent(x):
    """Quantization is a projection: q(q(x)) == q(x)."""
    xq = quantize_mxfp4(jnp.asarray(x))
    assert jnp.array_equal(quantize_mxfp4(xq), xq)


@settings(max_examples=30, deadline=None)
@given(_f32)
def test_m2xfp_act_near_idempotent(x):
    """Elem-EM fake-quant is idempotent up to ONE FP6 step: a refined FP6
    value can re-round into the next FP4 bin whose {-1..+2} decode set
    clamps it (e.g. 0.75 -> FP4 1.0 -> 0.875). The *packed* roundtrip is
    exact (test_pack_decode_roundtrip); re-quantizing a dequantized tensor
    is not a pipeline operation."""
    xj = jnp.asarray(x)
    q1 = quantize_act_m2xfp(xj)
    q2 = quantize_act_m2xfp(q1)
    xg = q1.reshape(-1, 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = jnp.exp2(shared_scale_exponent(amax, "floor").astype(jnp.float32))
    drift = jnp.abs(q2.reshape(-1, 32) - xg)
    assert bool(jnp.all(drift <= 0.25 * s + 1e-7))


@settings(max_examples=30, deadline=None)
@given(_f32)
def test_mxfp4_error_bound(x):
    """|x - q(x)| <= max(half grid step at |x|, clip error) * scale; the
    coarse bound ulp = 2 * scale covers every grid interval of E2M1."""
    xj = jnp.asarray(x)
    dq = quantize_mxfp4(xj)
    xg = xj.reshape(-1, 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = jnp.exp2(shared_scale_exponent(amax, "floor").astype(jnp.float32))
    err = jnp.abs((dq.reshape(-1, 32) - xg))
    # elements within +-6s: err <= 1s (half of largest step 2s);
    # clipped elements (floor rule allows amax < 8s): err < 2s
    assert bool(jnp.all(err <= 2.0 * s + 1e-6))


@settings(max_examples=30, deadline=None)
@given(_f32)
def test_sign_preservation(x):
    dq = quantize_act_m2xfp(jnp.asarray(x))
    assert bool(jnp.all(jnp.asarray(x) * dq >= 0))          # no sign flips


@settings(max_examples=30, deadline=None)
@given(_f32)
def test_m2xfp_never_worse_than_mxfp4_groupwise(x):
    """Elem-EM refinement only moves the top-1 closer to its true value:
    group MSE(m2xfp) <= group MSE(mxfp4) + tiny slack for the dropped
    -2 candidate."""
    xj = jnp.asarray(x)
    base = jnp.mean((quantize_mxfp4(xj) - xj) ** 2)
    m2 = jnp.mean((quantize_act_m2xfp(xj) - xj) ** 2)
    assert float(m2) <= float(base) * 1.001 + 1e-9


@settings(max_examples=20, deadline=None)
@given(_f32)
def test_pack_decode_roundtrip(x):
    xj = jnp.asarray(x)
    assert jnp.array_equal(decode_act_m2xfp(encode_act_m2xfp(xj)),
                           quantize_act_m2xfp(xj))


@settings(max_examples=30, deadline=None)
@given(st.floats(1e-20, 1e20, allow_nan=False, allow_infinity=False))
def test_scale_monotone(a):
    """Shared scale exponent is monotone in amax."""
    e1 = int(shared_scale_exponent(jnp.float32(a), "floor"))
    e2 = int(shared_scale_exponent(jnp.float32(a * 2), "floor"))
    assert e2 >= e1


@settings(max_examples=30, deadline=None)
@given(st.floats(-7.5, 7.5, allow_nan=False))
def test_fp6_round_is_nearest(v):
    from repro.core import FP6_MAG_VALUES
    got = float(round_to_grid(jnp.float32(v), FP6_E2M3))
    grid = np.asarray(FP6_MAG_VALUES, dtype=np.float64)
    grid = np.concatenate([-grid[::-1], grid])
    best = float(grid[np.argmin(np.abs(grid - v))])
    assert abs(got - v) <= abs(best - v) + 1e-7


# ---------------------------------------------------------------------------
# Packing-layer idempotence and KV-cache (Sg-EM) encode bounds
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(_u8)
def test_pack_unpack_pack_idempotent(stream):
    """The bit-level packers are exact inverses in both directions:
    pack(unpack(bytes)) == bytes for any byte stream, and
    unpack(pack(codes)) == codes for any in-range code array. (Idempotence
    does NOT hold at the value layer — re-encoding a dequantized tensor
    may re-round; see test_m2xfp_act_near_idempotent.)"""
    s = jnp.asarray(stream)
    assert jnp.array_equal(pack_nibbles(unpack_nibbles(s)), s)
    assert jnp.array_equal(pack_meta2(unpack_meta2(s, 4 * s.shape[-1])), s)
    codes = unpack_nibbles(s)                # arbitrary 4-bit codes
    assert jnp.array_equal(unpack_nibbles(pack_nibbles(codes)), codes)


@settings(max_examples=25, deadline=None)
@given(_f32_extreme)
def test_kv_roundtrip_finite_and_sign_preserving(x):
    """KV decode is total: for ANY finite f32 page content — subnormals,
    +-0, max-exponent values — the Sg-EM round-trip is finite, NaN-free
    and never flips a sign. Exact zeros decode to exact zeros."""
    xj = jnp.asarray(x)
    dq = kv_decode(kv_encode(xj)).astype(jnp.float32)
    assert bool(jnp.all(jnp.isfinite(dq)))
    assert bool(jnp.all(xj * dq >= 0))
    assert bool(jnp.all(jnp.where(xj == 0, dq == 0, True)))


@settings(max_examples=25, deadline=None)
@given(_f32_extreme)
def test_kv_scale_bytes_in_e8m0_range(x):
    """Encoded E8M0 scale bytes stay in [1, 254]: the exponent clamp means
    the 0 byte (2^-127 would alias) and the 255 NaN code are never
    produced, so decode never manufactures a NaN from a valid page."""
    enc = kv_encode(jnp.asarray(x))
    sb = enc["scales"]
    assert bool(jnp.all((sb >= 1) & (sb <= 254)))
    # streams have the advertised 4.5 bits/elem footprint
    n = x.size
    assert enc["codes"].size == n // 2
    assert enc["scales"].size == enc["meta"].size == n // 32


@settings(max_examples=25, deadline=None)
@given(_f32)
def test_kv_reencode_drift_bounded(x):
    """Re-encoding a decoded KV page moves values by at most half an FP4
    step at the group scale (0.5 * 2^e): the round-trip is stable, it
    cannot walk values away under repeated quantization."""
    d1 = kv_decode(kv_encode(jnp.asarray(x))).astype(jnp.float32)
    d2 = kv_decode(kv_encode(d1)).astype(jnp.float32)
    g1 = d1.reshape(-1, 32)
    amax = jnp.max(jnp.abs(g1), axis=-1, keepdims=True)
    s = jnp.exp2(shared_scale_exponent(amax, "floor").astype(jnp.float32))
    drift = jnp.abs(d2.reshape(-1, 32) - g1)
    # relative slack: the 0.5*s bound is attained exactly, modulo f32 ulps
    assert bool(jnp.all(drift <= 0.5 * s * 1.00001 + 1e-7))


def test_kv_edge_values_exact():
    """Pinned edge rows (not strategies, so they always run): min
    subnormal, min normal, -0.0 and f32 max all survive the round-trip
    finite; the all-zero row is reproduced exactly."""
    edges = np.zeros((4, 64), np.float32)
    edges[1, :] = np.float32(1e-45)
    edges[2, ::2] = np.float32(-0.0)
    edges[2, 1::2] = np.finfo(np.float32).tiny
    edges[3, :] = np.finfo(np.float32).max
    dq = np.asarray(kv_decode(kv_encode(jnp.asarray(edges)))
                    .astype(jnp.float32))
    assert np.isfinite(dq).all() and not np.isnan(dq).any()
    assert (dq[0] == 0).all()
    assert (dq * edges >= 0).all()


@settings(max_examples=15, deadline=None)
@given(_f32, st.sampled_from([1, 2, 4]))
def test_weight_scale_multiplier_search_optimal(x, bits_unused):
    """Sg-EM fixed-scale pick is at least as good as any single k."""
    from repro.core.m2xfp import sg_em_dequant_with_scale
    from repro.core.packing import group_reshape
    from repro.core.dtypes import round_to_grid as rtg
    xg = group_reshape(jnp.asarray(x), 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = jnp.exp2(shared_scale_exponent(amax, "floor").astype(jnp.float32))
    best = sg_em_dequant_with_scale(xg, s, 8, bits=2, adaptive=False)
    err_best = float(jnp.sum((best - xg) ** 2))
    for k in range(4):
        sk = (1 + k / 4) * s
        dq = rtg(xg / sk, FP4_E2M1) * sk
        assert err_best <= float(jnp.sum((dq - xg) ** 2)) + 1e-5
