"""Attention: chunked flash == dense reference; windows; q-tiling; decode
ring cache == prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _chunked_attention, attention_decode, attention_forward,
    cache_from_prefill, init_attention, init_cache,
)
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)
CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16)


def _qkv(rng, b=2, s=96, nh=4, nkv=2, hd=16):
    q = jnp.asarray(rng.standard_normal((b, s, nh, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, nkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, nkv, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    return q, k, v, pos


def _dense_ref(q, k, v, pos, window, softcap=None):
    nrep = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, nrep, axis=2)
    vv = jnp.repeat(v, nrep, axis=2)
    s = jnp.einsum("bsnd,btnd->bnst",
                   q.astype(jnp.bfloat16).astype(jnp.float32),
                   kk.astype(jnp.bfloat16).astype(jnp.float32))
    s = s * q.shape[-1] ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pq, pk = pos[:, :, None], pos[:, None, :]
    mask = (pq >= pk) & (pq - pk < window)
    s = jnp.where(mask[:, None], s, -2e38)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum(
        "bnst,btnd->bsnd",
        p.astype(jnp.bfloat16).astype(jnp.float32), vv)


@pytest.mark.parametrize("window", [1 << 30, 32])
@pytest.mark.parametrize("chunk,q_tile", [(32, 1 << 30), (32, 32), (96, 48)])
def test_chunked_equals_dense(window, chunk, q_tile):
    rng = np.random.default_rng(0)
    q, k, v, pos = _qkv(rng)
    ref = jax.jit(lambda q, k, v: _dense_ref(q, k, v, pos, window))(q, k, v)
    got = jax.jit(lambda q, k, v: _chunked_attention(
        q, k, v, pos, pos, CFG, jnp.int32(window), chunk=chunk,
        q_tile=q_tile))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)


def test_softcap_applied():
    import dataclasses
    cfg = dataclasses.replace(CFG, attn_softcap=5.0)
    rng = np.random.default_rng(1)
    q, k, v, pos = _qkv(rng)
    q = q * 10  # force big logits so the cap matters
    ref = jax.jit(lambda q, k, v: _dense_ref(
        q, k, v, pos, 1 << 30, softcap=5.0))(q, k, v)
    got = jax.jit(lambda q, k, v: _chunked_attention(
        q, k, v, pos, pos, cfg, jnp.int32(1 << 30), chunk=32))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-2, rtol=5e-2)


def test_ring_cache_decode_matches_forward():
    """Windowed ring cache: decode over a long stream == windowed forward."""
    import dataclasses
    cfg = dataclasses.replace(CFG, sliding_window=16)
    p = init_attention(KEY, cfg)
    rng = np.random.default_rng(2)
    b, s = 2, 40
    x = jnp.asarray(rng.standard_normal((b, s, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    full, _ = jax.jit(lambda p, x: attention_forward(
        p, x, cfg, pos, window=jnp.int32(16)))(p, x)
    cache = init_cache(cfg, b, s, window=16)
    step = jax.jit(lambda p, x, c, i: attention_decode(
        p, x, cfg, c, i, window=jnp.int32(16)))
    outs = []
    for t in range(s):
        o, cache = step(p, x[:, t:t + 1], cache, jnp.int32(t))
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    assert err < 0.08, err
    # ring buffer really is bounded at the window size
    assert cache["k"].shape[1] == 16


def test_cache_from_prefill_consistent():
    import dataclasses
    cfg = dataclasses.replace(CFG, sliding_window=16)
    p = init_attention(KEY, cfg)
    rng = np.random.default_rng(3)
    b, s = 2, 32
    x = jnp.asarray(rng.standard_normal((b, s + 1, 64)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    _, (k, v) = jax.jit(lambda p, x: attention_forward(
        p, x[:, :s], cfg, pos, window=jnp.int32(16)))(p, x)
    cache = cache_from_prefill(k, v, pos, window=16)
    o1, _ = attention_decode(p, x[:, s:s + 1], cfg, cache, jnp.int32(s),
                             window=jnp.int32(16))
    # reference: decode step-by-step from scratch
    cache2 = init_cache(cfg, b, s + 1, window=16)
    step = jax.jit(lambda p, x, c, i: attention_decode(
        p, x, cfg, c, i, window=jnp.int32(16)))
    for t in range(s + 1):
        o2, cache2 = step(p, x[:, t:t + 1], cache2, jnp.int32(t))
    err = float(jnp.max(jnp.abs(o1.astype(jnp.float32)
                                - o2.astype(jnp.float32))))
    assert err < 0.08, err
