"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; decode-vs-prefill consistency; quantized
modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.configs.shapes import applicable_shapes
from repro.models.model import (
    decode_step, forward, init_caches, init_params, loss_fn,
    pack_params_for_serving,
)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "embeddings":
        out = {"embeds": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), dtype=jnp.bfloat16)}
    else:
        out = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), dtype=jnp.int32)}
    out["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), dtype=jnp.int32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, _batch(cfg))))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_prefill(arch):
    """Greedy decode logits at position t == prefill logits at t (recurrent
    families exactly define this; attention via cache). MoE archs get a
    high capacity factor: token dropping depends on the routing-group
    population, which legitimately differs between prefill and decode."""
    cfg = smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_params(KEY, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s, seed=1)
    full = jax.jit(lambda p, bb: forward(p, cfg, bb))(params, batch)
    caches = init_caches(cfg, b, s)
    dec = jax.jit(lambda p, bb, c, i: decode_step(p, cfg, bb, c, i))
    outs = []
    for t in range(s):
        if cfg.input_mode == "embeddings":
            db = {"embeds": batch["embeds"][:, t:t + 1]}
        else:
            db = {"tokens": batch["tokens"][:, t:t + 1]}
        lg, caches = dec(params, db, caches, jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec_logits - full.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(full.astype(jnp.float32)))) + 1e-6
    assert err / scale < 0.08, (arch, err, scale)


@pytest.mark.parametrize("quant", ["qat", "serve"])
def test_quantized_modes(quant):
    """The paper's technique as a first-class mode: qat trains, serve runs
    on packed 4.5-bit weights; both stay close to the bf16 forward."""
    cfg = smoke_config("paper-llama2-7b")
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    base = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    qcfg = dataclasses.replace(cfg, quant=quant)
    qparams = pack_params_for_serving(params, qcfg) if quant == "serve" \
        else params
    out = jax.jit(lambda p, b: forward(p, qcfg, b))(qparams, batch)
    assert not bool(jnp.any(jnp.isnan(out.astype(jnp.float32))))
    # W4A4 changes outputs but must stay correlated with the bf16 model
    # (random-init weights — trained-model fidelity is asserted end-to-end
    # in test_system.py and the accuracy-proxy benchmark)
    a = base.astype(jnp.float32).ravel()
    bv = out.astype(jnp.float32).ravel()
    corr = float(jnp.corrcoef(jnp.stack([a, bv]))[0, 1])
    assert corr > 0.85, corr
    if quant == "qat":
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: loss_fn(p, qcfg, batch)))(qparams)
        assert np.isfinite(float(loss))


def test_serve_packing_shrinks_footprint():
    cfg = dataclasses.replace(smoke_config("qwen3-8b"), quant="serve")
    params = init_params(KEY, cfg)
    packed = pack_params_for_serving(params, cfg)

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

    # big GEMM weights shrink ~3.5x (16 -> 4.5 bits); embeddings stay bf16
    assert nbytes(packed) < 0.65 * nbytes(params)


def test_all_archs_have_four_shape_rows():
    total = 0
    for arch in ARCHS[:-1]:
        cfg = get_config(arch)
        n = len(applicable_shapes(cfg))
        assert n in (3, 4)
        total += 4                      # nominal cells incl. documented skips
    assert total == 40
