"""Codec registry tests: lookup/error contract, round-trip parity of every
packed codec against its fake-quant reference, PackedTensor pytree
behavior, and the EBW accounting of the packed streams."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codecs import (
    Codec, PackedTensor, get_codec, kernel_codecs, kv_codecs, list_codecs,
    packed_codecs, register_codec,
)
from repro.models.quant import (
    decode_serving_weight, fake_quant_act, fake_quant_weight,
    pack_serving_weight,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_registry_lists_every_paper_format():
    names = list_codecs()
    for fmt in ("m2xfp", "m2nvfp4", "mxfp4", "nvfp4", "smx4", "fp4",
                "m2xfp_ideal6"):
        assert fmt in names
    assert names == tuple(sorted(names))
    # subsets are consistent
    assert set(packed_codecs()) <= set(names)
    assert set(kernel_codecs()) <= set(packed_codecs())
    assert set(kv_codecs()) <= set(names)
    assert "nvfp4" in packed_codecs()
    assert "nvfp4" not in kernel_codecs()    # serves via the XLA mirror
    assert "nvfp4" not in kv_codecs()        # per-call tensor scale
    # the per-tensor activation scale also breaks launch-shape invariance
    assert not get_codec("nvfp4").act_batch_invariant
    assert get_codec("m2xfp").act_batch_invariant
    assert get_codec("mxfp4").act_batch_invariant


def test_unknown_codec_error_lists_registry():
    with pytest.raises(ValueError, match="unknown codec 'int3'"):
        get_codec("int3")
    with pytest.raises(ValueError, match="m2xfp"):
        get_codec("int3")                    # message names the options


def test_fake_quant_rejects_unknown_format():
    w = jnp.ones((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="unknown codec"):
        fake_quant_weight(w, "bogus")
    with pytest.raises(ValueError, match="unknown codec"):
        fake_quant_act(w, "bogus")


def test_pack_rejects_unpackable_codec():
    w = jnp.ones((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="smx4"):
        pack_serving_weight(w, "smx4")       # fake-quant only, no streams


def test_kv_rejects_non_kv_codec():
    from repro.models.kvquant import kv_codec
    with pytest.raises(ValueError, match="no packed KV-cache path"):
        kv_codec("nvfp4")
    with pytest.raises(ValueError, match="unknown codec"):
        kv_codec("bogus")


def test_register_codec_rejects_duplicates_and_accepts_toys():
    fq = lambda x: x
    toy = Codec(name="test-toy", group=32, ebw=4.0,
                fake_quant_weight=fq, fake_quant_act=fq)
    register_codec(toy)
    try:
        assert "test-toy" in list_codecs()
        assert not get_codec("test-toy").packed
        with pytest.raises(ValueError, match="already registered"):
            register_codec(toy)
        register_codec(toy, overwrite=True)  # explicit overwrite allowed
    finally:
        from repro.core import codecs as _c
        _c._REGISTRY.pop("test-toy", None)


# ---------------------------------------------------------------------------
# Round-trip parity: decode(encode(w)) == fake_quant(w), bit-exact, for
# every codec that can be packed (the serve path's core invariant)
# ---------------------------------------------------------------------------

@pytest.mark.smoke
@pytest.mark.parametrize("fmt", packed_codecs())
def test_packed_roundtrip_matches_fake_quant(fmt):
    w = jax.random.normal(KEY, (64, 128), jnp.float32) * 3.0
    p = pack_serving_weight(w, fmt)
    assert isinstance(p, PackedTensor) and p.codec == fmt
    dec = decode_serving_weight(p, dtype=jnp.float32)
    ref = fake_quant_weight(w, fmt).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))


@pytest.mark.parametrize("fmt", packed_codecs())
def test_packed_roundtrip_edge_values(fmt):
    """Zeros, tiny denormals and huge groups survive the scale guards."""
    w = np.zeros((64, 128), np.float32)
    w[0, :] = 1e-30                          # underflowing group
    w[32, :] = 3e4                           # near-saturating group
    w[33, 1] = -7.0
    p = pack_serving_weight(jnp.asarray(w), fmt)
    dec = decode_serving_weight(p, dtype=jnp.float32)
    ref = fake_quant_weight(jnp.asarray(w), fmt).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))


@pytest.mark.parametrize("fmt", packed_codecs())
def test_packed_stream_footprint_matches_ebw(fmt):
    """Stream bytes per element == the codec's advertised EBW (tscale's
    per-tensor 4 bytes amortize to ~0 and are excluded)."""
    k, n = 128, 256
    p = pack_serving_weight(jax.random.normal(KEY, (k, n), jnp.float32), fmt)
    nbytes = sum(v.size * v.dtype.itemsize
                 for name, v in p.streams.items() if name != "tscale")
    assert 8 * nbytes / (k * n) == pytest.approx(get_codec(fmt).ebw)


# ---------------------------------------------------------------------------
# PackedTensor pytree behavior
# ---------------------------------------------------------------------------

def test_packed_tensor_pytree_roundtrip_and_vmap():
    ws = jax.random.normal(KEY, (3, 64, 128), jnp.float32)
    for fmt in packed_codecs():
        stacked = jax.vmap(lambda w: pack_serving_weight(w, fmt))(ws)
        assert isinstance(stacked, PackedTensor)
        assert stacked.codec == fmt and stacked.shape == (64, 128)
        # flatten/unflatten preserves streams, shape and codec tag
        leaves, tdef = jax.tree_util.tree_flatten(stacked)
        back = jax.tree_util.tree_unflatten(tdef, leaves)
        assert back.codec == fmt and back.shape == stacked.shape
        # per-layer slices decode to the per-layer pack
        one = pack_serving_weight(ws[1], fmt)
        for name in one.streams:
            np.testing.assert_array_equal(np.asarray(stacked.streams[name][1]),
                                          np.asarray(one.streams[name]))


def test_packed_tensor_keyed_paths_name_streams():
    p = pack_serving_weight(jnp.ones((32, 128), jnp.float32), "m2xfp")
    flat = jax.tree_util.tree_flatten_with_path(p)[0]
    names = {path[-1].name for path, _ in flat}
    assert names == {"codes", "scales", "meta"}


def test_decode_dtype_per_codec():
    w = jax.random.normal(KEY, (64, 128), jnp.float32)
    assert decode_serving_weight(
        pack_serving_weight(w, "m2xfp")).dtype == jnp.bfloat16
    # nvfp4's e4m3 x f32 scale product is not bf16-representable
    assert decode_serving_weight(
        pack_serving_weight(w, "nvfp4")).dtype == jnp.float32
