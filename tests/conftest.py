import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def heavy_tailed(rng, shape, df=4.0, ch_sigma=0.8):
    """LLM-like tensor: student-t entries with per-channel log-normal scale."""
    t = rng.standard_t(df=df, size=shape).astype(np.float32)
    ch = np.exp(ch_sigma * rng.standard_normal((1, shape[-1]))).astype(
        np.float32)
    return t * ch
