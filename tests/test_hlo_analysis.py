"""Loop-aware HLO analyzer: exact FLOPs on a known scanned matmul."""
import os
import subprocess
import sys
import json
import textwrap

import pytest

from repro.analysis.hlo import parse_bytes_of_shape

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_parse_bytes():
    assert parse_bytes_of_shape("bf16[8,512]{1,0}") == 8 * 512 * 2
    assert parse_bytes_of_shape("(f32[2,2], u8[4])") == 16 + 4
    assert parse_bytes_of_shape("pred[]") == 1
    assert parse_bytes_of_shape("s32[10]") == 40


@pytest.mark.slow
def test_flops_exact_under_scan():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.analysis.hlo import analyze_hlo
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh((2, 4), ("data", "model"))
        L, D, B = 7, 256, 64

        def step(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, ws)
            return h.sum()

        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
        with mesh:
            f = jax.jit(step, in_shardings=(
                NamedSharding(mesh, P(None, "data", "model")),
                NamedSharding(mesh, P("data", None))))
            comp = f.lower(ws, xs).compile()
        a = analyze_hlo(comp.as_text())
        expected = 2 * B * D * D * L / 8
        print(json.dumps({"ratio": a.flops / expected,
                          "trips": list(a.loop_trips.values())}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(r["ratio"] - 1.0) < 1e-6
    assert 7 in r["trips"]
