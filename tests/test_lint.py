"""reprolint: per-rule fixtures (hit / suppressed / clean), baseline
round-trips, the CLI, and a meta-test that the live tree is clean modulo
the committed baseline."""
import json
import os
import textwrap

import pytest

from repro.analysis.lint import (
    RULES, Violation, baseline_path, diff_against_baseline, lint_paths,
    lint_source, load_baseline, save_baseline,
)
from repro.analysis.lint.cli import main as lint_main
from repro.analysis.lint.core import repo_root
from repro.analysis.lint.report import (
    render_json, render_summary, rule_counts,
)
from repro.core import envflags


def _lint(src, relpath="src/repro/models/fixture.py", only=None):
    return lint_source(textwrap.dedent(src), relpath, only=only)


def _rules_hit(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# framework basics
# ---------------------------------------------------------------------------

def test_all_expected_rules_registered():
    expected = {
        "env-hygiene", "donated-reuse", "undrained-callback", "tracer-leak",
        "codec-contract", "kernel-contract", "bare-except",
        "mutable-default", "missing-all",
    }
    assert expected <= set(RULES)


def test_syntax_error_becomes_parse_error_violation():
    vs = _lint("def broken(:\n")
    assert [v.rule for v in vs] == ["parse-error"]


def test_violation_format_and_ident():
    v = Violation("some-rule", "a/b.py", 3, 7, "msg")
    assert v.format() == "a/b.py:3:7: error: [some-rule] msg"
    assert v.ident() == ("a/b.py", "some-rule", "msg")


def test_file_level_suppression():
    src = """\
    # reprolint: disable-file=bare-except
    try:
        pass
    except:
        pass
    """
    assert _lint(src, only=["bare-except"]) == []


# ---------------------------------------------------------------------------
# env-hygiene
# ---------------------------------------------------------------------------

ENV_HIT = """\
import os
chunk = os.environ.get("REPRO_ATTN_KV_CHUNK", "512")
"""


def test_env_hygiene_hit():
    assert _rules_hit(_lint(ENV_HIT)) == {"env-hygiene"}


def test_env_hygiene_getenv_subscript_and_contains():
    src = """\
    import os
    a = os.getenv("REPRO_X")
    b = os.environ["REPRO_Y"]
    c = "REPRO_Z" in os.environ
    """
    assert len(_lint(src, only=["env-hygiene"])) == 3


def test_env_hygiene_allows_envflags_module_and_non_repro():
    assert _lint(ENV_HIT, relpath="src/repro/core/envflags.py") == []
    assert _lint('import os\nx = os.environ.get("PATH")\n',
                 only=["env-hygiene"]) == []


def test_env_hygiene_suppressed():
    src = ('import os\n'
           'x = os.environ.get("REPRO_X")'
           '  # reprolint: disable=env-hygiene -- bootstrap before registry\n')
    assert lint_source(src, "src/repro/models/fixture.py") == []


# ---------------------------------------------------------------------------
# donated-reuse
# ---------------------------------------------------------------------------

def test_donated_reuse_hit():
    src = """\
    import jax

    def run(fn, state, x):
        step = jax.jit(fn, donate_argnums=(0,))
        out = step(state, x)
        return out + state.mean()
    """
    vs = _lint(src, only=["donated-reuse"])
    assert len(vs) == 1 and "state" in vs[0].message


def test_donated_reuse_rebind_same_statement_is_clean():
    src = """\
    import jax

    def run(fn, state, x):
        step = jax.jit(fn, donate_argnums=(0,))
        state = step(state, x)
        return state
    """
    assert _lint(src, only=["donated-reuse"]) == []


def test_donated_reuse_self_attr_across_methods():
    src = """\
    import jax

    class Engine:
        def __init__(self, fn):
            self._step = jax.jit(fn, donate_argnums=(0,))

        def bad(self, caches, tok):
            out = self._step(caches, tok)
            return out, caches
    """
    vs = _lint(src, only=["donated-reuse"])
    assert len(vs) == 1 and "caches" in vs[0].message


# ---------------------------------------------------------------------------
# undrained-callback
# ---------------------------------------------------------------------------

CB_HIT = """\
import jax

def probe(stats):
    jax.debug.callback(print, stats)
"""


def test_undrained_callback_hit():
    assert _rules_hit(_lint(CB_HIT)) == {"undrained-callback"}


def test_undrained_callback_clean_with_barrier():
    src = CB_HIT + "\n\ndef drain():\n    jax.effects_barrier()\n"
    assert _lint(src, only=["undrained-callback"]) == []


def test_undrained_callback_suppressed():
    src = ("import jax\n\n"
           "def probe(stats):\n"
           "    jax.debug.callback(print, stats)"
           "  # reprolint: disable=undrained-callback -- drained elsewhere\n")
    assert lint_source(src, "src/repro/models/fixture.py") == []


# ---------------------------------------------------------------------------
# tracer-leak
# ---------------------------------------------------------------------------

def test_tracer_leak_float_and_item_in_jit():
    src = """\
    import jax

    @jax.jit
    def f(x):
        lo = float(x)
        hi = x.mean().item()
        return lo + hi
    """
    assert len(_lint(src, only=["tracer-leak"])) == 2


def test_tracer_leak_host_numpy_in_kernel_body():
    src = """\
    import numpy as np
    from jax.experimental import pallas as pl

    def _k(x_ref, o_ref):
        o_ref[...] = np.asarray(x_ref[...])

    def launch(x, grid, out_shape):
        return pl.pallas_call(_k, grid=grid, out_shape=out_shape)(x)
    """
    vs = _lint(src, only=["tracer-leak"])
    assert len(vs) == 1 and "np.asarray" in vs[0].message


def test_tracer_leak_branch_on_traced_value():
    src = """\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        if jnp.any(x > 0):
            return x
        return -x
    """
    assert len(_lint(src, only=["tracer-leak"])) == 1


def test_tracer_leak_kwonly_params_are_static():
    src = """\
    import jax
    import functools

    @functools.partial(jax.jit, static_argnames=("bm",))
    def f(x, *, bm):
        return x[: int(bm)]
    """
    assert _lint(src, only=["tracer-leak"]) == []


def test_tracer_leak_not_flagged_outside_jit():
    assert _lint("def f(x):\n    return float(x)\n",
                 only=["tracer-leak"]) == []


# ---------------------------------------------------------------------------
# codec-contract
# ---------------------------------------------------------------------------

CODEC_CLEAN = """\
from repro.core.codecs import Codec

C = Codec(name="mxfp4", group=32, ebw=4.25,
          fake_quant_weight=fqw, fake_quant_act=fqa)
"""


def test_codec_contract_clean():
    assert _lint(CODEC_CLEAN, only=["codec-contract"]) == []


def test_codec_contract_missing_required():
    src = "C = Codec(name='x', group=32)\n"
    vs = _lint(src, only=["codec-contract"])
    assert len(vs) == 1 and "missing required" in vs[0].message


def test_codec_contract_encode_without_decode():
    src = ("C = Codec(name='x', group=32, ebw=4.25, fake_quant_weight=f,\n"
           "          fake_quant_act=f, encode=enc,\n"
           "          scale_kind='e8m0', scale_sat_bounds=(1, 254))\n")
    vs = _lint(src, only=["codec-contract"])
    assert any("encode given without decode" in v.message for v in vs)


def test_codec_contract_ebw_mismatch():
    src = ("C = Codec(name='x', group=32, ebw=4.5, fake_quant_weight=f,\n"
           "          fake_quant_act=f)\n")
    vs = _lint(src, only=["codec-contract"])
    assert len(vs) == 1 and "4.25" in vs[0].message


def test_codec_contract_ebw_with_meta():
    src = ("C = Codec(name='x', group=32, ebw=4.5, has_meta=True,\n"
           "          fake_quant_weight=f, fake_quant_act=f)\n")
    assert _lint(src, only=["codec-contract"]) == []


def test_codec_contract_packed_e8m0_needs_sat_bounds():
    src = ("C = Codec(name='x', group=32, ebw=4.25, fake_quant_weight=f,\n"
           "          fake_quant_act=f, encode=e, decode=d,\n"
           "          scale_kind='e8m0')\n")
    vs = _lint(src, only=["codec-contract"])
    assert any("scale_sat_bounds" in v.message for v in vs)


def test_codec_contract_bad_sat_bounds():
    src = ("C = Codec(name='x', group=32, ebw=4.25, fake_quant_weight=f,\n"
           "          fake_quant_act=f, encode=e, decode=d,\n"
           "          scale_kind='e8m0', scale_sat_bounds=(0, 255))\n")
    vs = _lint(src, only=["codec-contract"])
    assert any("[1, 254]" in v.message for v in vs)


# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------

KERNEL_GRID_HIT = """\
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def launch(x, kernel, bm=128):
    m, n = x.shape
    grid = (m // bm,)
    return pl.pallas_call(
        kernel, grid=grid,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32))(x)
"""


def test_kernel_contract_unguarded_floordiv_grid():
    vs = _lint(KERNEL_GRID_HIT, only=["kernel-contract"])
    assert len(vs) == 1 and "remainder" in vs[0].message


def test_kernel_contract_modulo_raise_guards_grid():
    src = KERNEL_GRID_HIT.replace(
        "    grid = (m // bm,)",
        "    if m % bm:\n        raise ValueError(m)\n    grid = (m // bm,)")
    assert _lint(src, only=["kernel-contract"]) == []


def test_kernel_contract_missing_geometry():
    src = """\
    from jax.experimental import pallas as pl

    def launch(x, kernel):
        return pl.pallas_call(kernel)(x)
    """
    vs = _lint(src, only=["kernel-contract"])
    assert sorted("grid" in v.message for v in vs) == [False, True]
    assert len(vs) == 2


def test_kernel_contract_dot_needs_f32_accumulation():
    src = """\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _k(x_ref, w_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], w_ref[...])

    def launch(x, w, grid, out_shape):
        return pl.pallas_call(_k, grid=grid, out_shape=out_shape)(x, w)
    """
    vs = _lint(src, only=["kernel-contract"])
    assert len(vs) == 1 and "preferred_element_type" in vs[0].message
    fixed = src.replace(
        "jnp.dot(x_ref[...], w_ref[...])",
        "jnp.dot(x_ref[...], w_ref[...], "
        "preferred_element_type=jnp.float32)")
    assert _lint(fixed, only=["kernel-contract"]) == []


# ---------------------------------------------------------------------------
# hygiene rules
# ---------------------------------------------------------------------------

def test_bare_except_hit_and_typed_clean():
    hit = "try:\n    pass\nexcept:\n    pass\n"
    clean = "try:\n    pass\nexcept ValueError:\n    pass\n"
    assert _rules_hit(_lint(hit)) == {"bare-except"}
    assert _lint(clean, only=["bare-except"]) == []


def test_mutable_default_hit_and_clean():
    assert len(_lint("def f(x, acc=[]):\n    return acc\n",
                     only=["mutable-default"])) == 1
    assert len(_lint("def f(x, *, cfg=dict()):\n    return cfg\n",
                     only=["mutable-default"])) == 1
    assert _lint("def f(x, acc=None):\n    return acc or []\n",
                 only=["mutable-default"]) == []


def test_missing_all_only_fires_on_repro_package_init():
    src = "from .mod import thing\n"
    hit = _lint(src, relpath="src/repro/fake/__init__.py",
                only=["missing-all"])
    assert len(hit) == 1 and hit[0].severity == "warning"
    assert _lint(src, relpath="src/other/__init__.py",
                 only=["missing-all"]) == []
    assert _lint(src + '\n__all__ = ["thing"]\n',
                 relpath="src/repro/fake/__init__.py",
                 only=["missing-all"]) == []


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    vs = _lint(ENV_HIT + CB_HIT.replace("import jax\n", ""))
    assert len(vs) == 2
    bpath = str(tmp_path / "lint-baseline.json")
    save_baseline(bpath, vs, justification="test fixture")
    entries = load_baseline(bpath)
    assert len(entries) == 2
    assert all(e["justification"] == "test fixture" for e in entries)

    new, stale = diff_against_baseline(vs, entries)
    assert new == [] and stale == []

    # fixing one violation leaves a stale entry
    new, stale = diff_against_baseline(vs[:1], entries)
    assert new == [] and len(stale) == 1

    # a fresh violation is new even with a baseline present
    extra = Violation("bare-except", "src/repro/x.py", 9, 1, "msg")
    new, stale = diff_against_baseline(list(vs) + [extra], entries)
    assert [v.rule for v in new] == ["bare-except"]


def test_baseline_counts_absorb_repeats(tmp_path):
    v = Violation("bare-except", "a.py", 1, 1, "m")
    w = Violation("bare-except", "a.py", 5, 1, "m")   # same identity
    bpath = str(tmp_path / "b.json")
    save_baseline(bpath, [v, w])
    entries = load_baseline(bpath)
    assert entries[0]["count"] == 2
    new, stale = diff_against_baseline([v, w], entries)
    assert new == [] and stale == []
    new, stale = diff_against_baseline([v], entries)
    assert new == [] and len(stale) == 1


def test_load_missing_baseline_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == []


def test_load_rejects_non_baseline_json(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("[1, 2, 3]")
    with pytest.raises(ValueError):
        load_baseline(str(p))


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------

def test_reporters():
    vs = _lint(ENV_HIT)
    assert rule_counts(vs) == {"env-hygiene": 1}
    summary = render_summary(vs)
    assert "env-hygiene" in summary and "1 violation" in summary
    payload = json.loads(render_json(vs))
    assert payload["counts"] == {"env-hygiene": 1}
    assert payload["violations"][0]["rule"] == "env-hygiene"
    assert "env-hygiene" in payload["rules"]
    assert render_summary([]) == "reprolint: clean (0 violations)"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exits_nonzero_on_violation(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    pass\nexcept:\n    pass\n")
    assert lint_main([str(bad), "--no-baseline"]) == 1
    out = capsys.readouterr().out
    assert "bare-except" in out


def test_cli_clean_file_exits_zero(tmp_path, capsys):
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint_main([str(good), "--no-baseline"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_rule_filter_and_unknown_rule(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    pass\nexcept:\n    pass\n")
    assert lint_main([str(bad), "--no-baseline",
                      "--rule", "env-hygiene"]) == 0
    capsys.readouterr()
    assert lint_main([str(bad), "--rule", "no-such-rule"]) == 2


def test_cli_update_then_check_baseline(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    pass\nexcept:\n    pass\n")
    bpath = str(tmp_path / "baseline.json")
    assert lint_main([str(bad), "--baseline", bpath,
                      "--update-baseline"]) == 0
    assert lint_main([str(bad), "--baseline", bpath]) == 0
    # fix the file: the baseline entry goes stale; --check-baseline fails
    bad.write_text("x = 1\n")
    assert lint_main([str(bad), "--baseline", bpath]) == 0
    capsys.readouterr()
    assert lint_main([str(bad), "--baseline", bpath,
                      "--check-baseline"]) == 1
    assert "stale" in capsys.readouterr().out


def test_cli_list_rules_and_env(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "kernel-contract" in out and "env-hygiene" in out
    assert lint_main(["--list-env"]) == 0
    out = capsys.readouterr().out
    assert "REPRO_FAITHFUL_DOTS" in out and "| Flag |" in out


# ---------------------------------------------------------------------------
# envflags registry
# ---------------------------------------------------------------------------

def test_envflags_semantics(monkeypatch):
    monkeypatch.delenv("REPRO_FAITHFUL_DOTS", raising=False)
    assert envflags.get_bool("REPRO_FAITHFUL_DOTS") is False
    monkeypatch.setenv("REPRO_FAITHFUL_DOTS", "1")
    assert envflags.get_bool("REPRO_FAITHFUL_DOTS") is True
    monkeypatch.setenv("REPRO_FAITHFUL_DOTS", "true")   # only "1" enables
    assert envflags.get_bool("REPRO_FAITHFUL_DOTS") is False

    monkeypatch.setenv("REPRO_ATTN_KV_CHUNK", "64")
    assert envflags.get_int("REPRO_ATTN_KV_CHUNK") == 64
    monkeypatch.setenv("REPRO_ATTN_KV_CHUNK", "zero")
    with pytest.raises(ValueError, match="not an integer"):
        envflags.get_int("REPRO_ATTN_KV_CHUNK")

    monkeypatch.setenv("REPRO_SERVE_KERNEL", "warp")
    with pytest.raises(ValueError, match="expected one of"):
        envflags.get_str("REPRO_SERVE_KERNEL")


def test_envflags_markdown_table_covers_registry():
    table = envflags.markdown_table()
    for flag in envflags.defined_flags():
        assert flag.name in table


# ---------------------------------------------------------------------------
# meta: the live tree is clean modulo the committed baseline
# ---------------------------------------------------------------------------

def test_live_tree_clean_modulo_baseline():
    root = repo_root()
    assert os.path.isdir(os.path.join(root, "src", "repro"))
    violations = lint_paths(root=root)
    entries = load_baseline(baseline_path(root))
    assert len(entries) <= 5, "baseline must stay small and justified"
    new, _ = diff_against_baseline(violations, entries)
    assert new == [], "\n".join(v.format() for v in new)
