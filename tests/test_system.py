"""End-to-end behaviour tests for the paper's system: train -> checkpoint
-> resume -> pack to M2XFP -> serve, with accuracy ordering preserved."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_state, save_state
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import (
    decode_step, forward, init_caches, init_params, loss_fn,
    pack_params_for_serving,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _cfg(**kw):
    from repro.models.config import ModelConfig
    base = dict(name="sys", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                head_dim=16, remat=False)
    base.update(kw)
    return ModelConfig(**base)


def _train(cfg, steps, resume_from=None, ckpt_dir=None, data_seed=2):
    data = SyntheticLM(DataConfig(batch=8, seq=32, vocab=cfg.vocab_size,
                                  seed=data_seed, motif_len=6, noise=0.02))
    params = init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    opt = adamw_init(params)
    start = 0
    if resume_from is not None:
        (params, opt), extra = restore_state(
            resume_from, (params, opt))
        start = extra["step"]
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=steps,
                       weight_decay=0.0)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, loss

    loss = jnp.inf
    for i in range(start, steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, loss = step_fn(params, opt, b)
        if ckpt_dir and i == steps // 2:
            save_state(ckpt_dir, 0, (params, opt), extra={"step": i + 1})
    return params, float(loss)


def test_resume_is_bitexact(tmp_path):
    """Fault tolerance: crash-resume from a mid-run checkpoint reproduces
    the uninterrupted run exactly (deterministic data + optimizer)."""
    cfg = _cfg()
    p_full, _ = _train(cfg, 20)
    ckdir = str(tmp_path / "ck")
    _train(cfg, 20, ckpt_dir=ckdir)                  # writes step-10 ckpt
    p_resumed, _ = _train(cfg, 20, resume_from=ckdir)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trained_then_served_quantized():
    """The deployment story: bf16 train -> pack M2XFP -> serve. The packed
    model must track the bf16 model closely and beat an MXFP4 deployment."""
    cfg = _cfg(vocab_size=128)
    params, final_loss = _train(cfg, 120)
    data = SyntheticLM(DataConfig(batch=8, seq=32, vocab=128, seed=99,
                                  motif_len=6, noise=0.02))
    ev = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    loss_fp = float(jax.jit(lambda p: loss_fn(p, cfg, ev))(params))
    scfg = dataclasses.replace(cfg, quant="serve")
    sparams = pack_params_for_serving(params, scfg)
    loss_m2 = float(jax.jit(lambda p: loss_fn(p, scfg, ev))(sparams))
    qcfg = dataclasses.replace(cfg, quant="qat", quant_format="mxfp4")
    loss_mx = float(jax.jit(lambda p: loss_fn(p, qcfg, ev))(params))

    assert loss_m2 < loss_mx, (loss_m2, loss_mx)
    assert loss_m2 - loss_fp < 0.75 * (loss_mx - loss_fp) + 1e-3

    # serve path also decodes autoregressively without NaNs
    caches = init_caches(scfg, 2, 8)
    tok = ev["tokens"][:2, :1]
    step = jax.jit(lambda p, b, c, i: decode_step(p, scfg, b, c, i))
    for t in range(4):
        lg, caches = step(sparams, {"tokens": tok}, caches, jnp.int32(t))
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))
