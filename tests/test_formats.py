"""Core format tests: grids, rounding, scale rules, baselines, M2XFP
encode/decode, EBW accounting, and the paper's worked encoding example."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FP4_MAG_VALUES, FP6_MAG_VALUES, FP4_E2M1, FP6_E2M3, FP8_E4M3,
    SCALE_RULES, format_ebw, quantize_act_m2xfp, quantize_fp4_fp16scale,
    quantize_mxfp4, quantize_nvfp4, quantize_smx4, quantize_weight_m2xfp,
    round_to_grid, shared_scale_exponent,
)
from repro.core.m2xfp import (
    decode_act_m2xfp, decode_weight_m2xfp, elem_em_encode_parts,
    encode_act_m2xfp, encode_weight_m2xfp,
)
from conftest import heavy_tailed


@pytest.mark.smoke
def test_grids():
    assert np.allclose(FP4_MAG_VALUES, [0, .5, 1, 1.5, 2, 3, 4, 6])
    assert float(FP6_MAG_VALUES[-1]) == 7.5
    assert len(FP6_MAG_VALUES) == 32
    assert FP4_E2M1.max_pow2 == 4.0 and FP4_E2M1.max_value == 6.0
    assert FP8_E4M3.max_value == 448.0


@pytest.mark.parametrize("v,expect", [
    (1.75, 2.0), (1.25, 1.0), (2.5, 2.0), (3.5, 4.0), (5.0, 4.0),
    (7.0, 6.0), (100.0, 6.0), (0.25, 0.0), (0.26, 0.5), (-2.5, -2.0),
])
def test_fp4_rtne(v, expect):
    assert float(round_to_grid(jnp.float32(v), FP4_E2M1)) == expect


def test_fp6_grid_roundtrip():
    # every FP6 grid point is a fixed point of rounding
    g = jnp.asarray(FP6_MAG_VALUES)
    assert jnp.all(round_to_grid(g, FP6_E2M3) == g)


def test_scale_rules_floor_vs_ceil():
    # floor: amax/S in [4, 8); ceil: amax/S <= 6 (no clipping)
    amax = jnp.asarray([0.1, 1.0, 5.0, 6.0, 7.0, 100.0])
    e_floor = shared_scale_exponent(amax, "floor")
    e_ceil = shared_scale_exponent(amax, "ceil")
    sf = jnp.exp2(e_floor.astype(jnp.float32))
    sc = jnp.exp2(e_ceil.astype(jnp.float32))
    assert jnp.all((amax / sf >= 4) & (amax / sf < 8))
    assert jnp.all(amax / sc <= 6.0 + 1e-6)
    # rtne == ceil for FP4 (paper Sec. 6.4)
    assert jnp.array_equal(e_ceil, shared_scale_exponent(amax, "rtne"))


def test_all_scale_rules_run():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                    dtype=jnp.float32)
    for rule in SCALE_RULES:
        dq = quantize_mxfp4(x, rule=rule)
        assert dq.shape == x.shape
        assert not jnp.any(jnp.isnan(dq))


@pytest.mark.smoke
def test_paper_encoding_example():
    """Paper Sec. 4.4: FP4 value 4 -> decode candidates {3.75, 4, 4.5, 5};
    values in (3.5, 3.625) suffer the single dropped-candidate rounding."""
    xg = jnp.asarray([[[4.0] + [0.1] * 7]])      # one subgroup of 8
    s = jnp.ones((1, 1, 1))
    for orig, expect in [(3.8, 3.75), (4.0, 4.0), (4.4, 4.5), (4.9, 5.0),
                         (3.55, 3.75),   # dropped -2 candidate (paper's case)
                         (5.2, 5.5)]:    # 5.2 RTNEs to FP4=6; clamped up
        xg2 = xg.at[0, 0, 0].set(orig)
        _, _, v6, meta, c4t = elem_em_encode_parts(xg2, s, 8)
        assert float(v6[0, 0, 0]) == expect, (orig, float(v6[0, 0, 0]))


def test_top1_lowest_index_tiebreak():
    # two elements with identical FP4 magnitude: lowest index refined
    xg = jnp.asarray([[[3.9, 4.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1]]])
    s = jnp.ones((1, 1, 1))
    _, onehot, _, _, _ = elem_em_encode_parts(xg, s, 8)
    oh = onehot.reshape(-1)
    assert float(oh[0]) == 1.0 and float(jnp.sum(oh)) == 1.0


@pytest.mark.smoke
def test_pack_roundtrip_matches_fake_quant(rng):
    x = jnp.asarray(heavy_tailed(rng, (64, 256)))
    assert jnp.array_equal(decode_act_m2xfp(encode_act_m2xfp(x)),
                           quantize_act_m2xfp(x))
    assert jnp.array_equal(decode_weight_m2xfp(encode_weight_m2xfp(x)),
                           quantize_weight_m2xfp(x))


@pytest.mark.smoke
def test_packed_footprint_is_4p5_bits(rng):
    x = jnp.asarray(heavy_tailed(rng, (32, 128)))
    p = encode_act_m2xfp(x)
    assert p.nbytes_per_elem * 8 == 4.5
    pw = encode_weight_m2xfp(x)
    assert pw.nbytes_per_elem * 8 == 4.5


def test_ebw_values():
    assert format_ebw("mxfp4") == 4.25
    assert format_ebw("nvfp4") == 4.5
    assert format_ebw("m2xfp") == 4.5
    assert format_ebw("smx4") == 4.0
    assert format_ebw("m2nvfp4") == 5.0


def test_error_ordering_heavy_tailed(rng):
    """Tbl. 2/3 qualitative ordering on LLM-like tensors: every M2XFP
    variant and NVFP4 beat MXFP4; SMX4 is worst. (The m2xfp-vs-nvfp4 margin
    is a model-level claim at matched EBW — asserted by the accuracy-proxy
    benchmark, not per-tensor.)"""
    x = jnp.asarray(heavy_tailed(rng, (256, 1024)))
    mse = lambda f: float(jnp.mean((f(x) - x) ** 2))
    m_m2w = mse(quantize_weight_m2xfp)
    m_m2a = mse(quantize_act_m2xfp)
    m_nv = mse(quantize_nvfp4)
    m_mx = mse(quantize_mxfp4)
    m_smx = mse(quantize_smx4)
    assert m_m2w < m_mx and m_m2a < m_mx and m_nv < m_mx
    assert m_mx < m_smx


def test_weight_adaptive_beats_fixed(rng):
    x = jnp.asarray(heavy_tailed(rng, (128, 512)))
    ada = float(jnp.mean((quantize_weight_m2xfp(x, adaptive=True) - x) ** 2))
    fix = float(jnp.mean((quantize_weight_m2xfp(x, adaptive=False) - x) ** 2))
    assert ada <= fix + 1e-9
