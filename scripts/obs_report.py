"""Render a text summary from an observability dump (docs/observability.md).

Reads the ``metrics.jsonl`` + ``trace.json`` files that ``repro.obs.dump``
(or ``REPRO_OBS_DIR`` autodump) leaves behind and prints:

  * counters and gauges, grouped by metric name with their labels
  * histograms: count / mean / estimated p50, p90, p99 from the cumulative
    bucket counts (linear interpolation inside the winning bucket)
  * the top-N quantization clip-rate layers — the first thing to look at
    when packed accuracy drifts
  * a span summary from the Chrome trace (count + total/mean wall time per
    span name)

JSONL dumps are append-only, so a directory can hold several snapshots of
the same metric; the *last* record per (name, labels) wins.

    PYTHONPATH=src python scripts/obs_report.py /tmp/obs
    PYTHONPATH=src python scripts/obs_report.py --metrics m.jsonl --top 5
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def load_metrics(path: str) -> dict:
    """Last record per (name, sorted labels) from an append-only JSONL."""
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            key = (rec["name"], tuple(sorted(rec["labels"].items())))
            out[key] = rec
    return out


def fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def quantile_from_buckets(buckets: dict, q: float):
    """Estimate the q-quantile from cumulative {le: count} buckets by
    linear interpolation inside the first bucket whose cumulative count
    reaches q*total. Returns None on an empty histogram; the +Inf bucket
    clamps to the largest finite bound."""
    total = buckets.get("+Inf", 0)
    if total <= 0:
        return None
    target = q * total
    prev_le, prev_n = 0.0, 0
    finite = [(float(le), n) for le, n in buckets.items() if le != "+Inf"]
    for le, n in sorted(finite):
        if n >= target:
            span = n - prev_n
            frac = 0.0 if span <= 0 else (target - prev_n) / span
            return prev_le + frac * (le - prev_le)
        prev_le, prev_n = le, n
    return finite[-1][0] if finite else None


def report_metrics(recs: dict, top: int) -> list[str]:
    lines = []
    by_kind = {"counter": [], "gauge": [], "histogram": []}
    for (name, _), rec in sorted(recs.items()):
        by_kind.setdefault(rec["type"], []).append(rec)

    for kind in ("counter", "gauge"):
        rows = by_kind[kind]
        if not rows:
            continue
        lines.append(f"== {kind}s ({len(rows)} series) ==")
        for rec in rows:
            lines.append(f"  {rec['name']}{fmt_labels(rec['labels'])} "
                         f"= {rec['value']:g}")
        lines.append("")

    hists = by_kind["histogram"]
    if hists:
        lines.append(f"== histograms ({len(hists)} series) ==")
        for rec in hists:
            n = rec["count"]
            mean = rec["sum"] / n if n else float("nan")
            qs = [quantile_from_buckets(rec["buckets"], q)
                  for q in (0.5, 0.9, 0.99)]
            qtxt = " ".join(
                f"p{int(q * 100)}={v:.4g}" if v is not None else
                f"p{int(q * 100)}=?"
                for q, v in zip((0.5, 0.9, 0.99), qs))
            lines.append(f"  {rec['name']}{fmt_labels(rec['labels'])}: "
                         f"count={n} mean={mean:.4g} {qtxt}")
        lines.append("")

    lines += report_health(recs)

    clip = [r for (name, _), r in sorted(recs.items())
            if name == "repro_quant_clip_rate"
            and r["labels"].get("kind") == "weight"]
    if clip:
        clip.sort(key=lambda r: -r["value"])
        lines.append(f"== top clip-rate layers (of {len(clip)}) ==")
        for rec in clip[:top]:
            lines.append(f"  {rec['labels'].get('layer', '?'):40s} "
                         f"clip_rate={rec['value']:.3e}")
        lines.append("")
    return lines


_HEALTH_NAMES = {0: "HEALTHY", 1: "DEGRADED", 2: "FAILED"}


def report_health(recs: dict) -> list[str]:
    """Serving-health section: the guard's state machine and fault
    counters (repro_guard_*, docs/robustness.md). Silent when the engine
    ran unguarded."""
    guard = [r for (name, _), r in sorted(recs.items())
             if name.startswith("repro_guard_")]
    if not guard:
        return []
    lines = ["== serving health (repro_guard_*) =="]
    for rec in guard:
        val = rec["value"]
        if rec["name"] == "repro_guard_health_state":
            state = _HEALTH_NAMES.get(int(val), "?")
            lines.append(f"  health state = {state} ({val:g})")
        else:
            short = rec["name"][len("repro_guard_"):]
            lines.append(f"  {short}{fmt_labels(rec['labels'])} = {val:g}")
    lines.append("")
    return lines


def report_trace(path: str) -> list[str]:
    with open(path) as f:
        trace = json.load(f)
    spans = [e for e in trace.get("traceEvents", []) if e.get("ph") == "X"]
    if not spans:
        return ["== trace: no complete spans =="]
    agg = {}
    for e in spans:
        a = agg.setdefault(e["name"], [0, 0.0])
        a[0] += 1
        a[1] += e.get("dur", 0.0)
    lines = [f"== trace spans ({len(spans)} events, "
             f"{len(agg)} names) =="]
    for name, (n, dur) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        lines.append(f"  {name:28s} n={n:<5d} total={dur / 1e3:9.2f}ms "
                     f"mean={dur / n / 1e3:8.3f}ms")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("directory", nargs="?", default=None,
                    help="dump directory holding metrics.jsonl / trace.json "
                         "(default: $REPRO_OBS_DIR)")
    ap.add_argument("--metrics", default=None, help="explicit metrics.jsonl")
    ap.add_argument("--trace", default=None, help="explicit trace.json")
    ap.add_argument("--top", type=int, default=10,
                    help="clip-rate layers to show")
    args = ap.parse_args(argv)

    from repro.core import envflags
    d = args.directory or envflags.get_str("REPRO_OBS_DIR") or None
    metrics = args.metrics or (d and os.path.join(d, "metrics.jsonl"))
    trace = args.trace or (d and os.path.join(d, "trace.json"))
    if not metrics and not trace:
        ap.error("give a dump directory, --metrics, or --trace "
                 "(or set REPRO_OBS_DIR)")

    lines = []
    if metrics and os.path.exists(metrics):
        recs = load_metrics(metrics)
        lines.append(f"metrics: {metrics} ({len(recs)} series)")
        lines += report_metrics(recs, args.top)
    elif metrics:
        lines.append(f"metrics: {metrics} (missing)")
    if trace and os.path.exists(trace):
        lines += report_trace(trace)
    elif trace:
        lines.append(f"trace: {trace} (missing)")
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    sys.exit(main())
