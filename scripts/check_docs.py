#!/usr/bin/env python
"""Fail if documentation code snippets drift from the shipped API.

Extracts every fenced ```python block from README.md and docs/*.md and
executes each file's blocks, in order, in one fresh interpreter per file
(so a snippet may build on the previous one, like a reader following
along). A block whose info string is anything other than exactly
``python`` (e.g. ``python skip``, ``bash``, ``text``) is not executed.

    PYTHONPATH=src python scripts/check_docs.py [--only README.md]

Exit code 0 = every snippet ran; 1 = at least one failed (the offending
file, block index and traceback are printed).
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE = re.compile(
    r"^```(?P<info>[^\n`]*)\n(?P<body>.*?)^```\s*$",
    re.MULTILINE | re.DOTALL)


def doc_files(only: str | None = None):
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(
            os.path.join(docs, f) for f in os.listdir(docs)
            if f.endswith(".md"))
    if only:
        files = [f for f in files if os.path.basename(f) == only]
    return [f for f in files if os.path.exists(f)]


def python_blocks(path: str):
    with open(path) as f:
        text = f.read()
    return [m.group("body") for m in _FENCE.finditer(text)
            if m.group("info").strip() == "python"]


def run_file_blocks(path: str, blocks) -> bool:
    """Concatenate a file's blocks (separated by markers) and run them."""
    src = []
    for i, body in enumerate(blocks):
        src.append(f"print('--- block {i} ---', flush=True)")
        src.append(body)
    with tempfile.NamedTemporaryFile(
            "w", suffix=".py", delete=False) as tf:
        tf.write("\n".join(src))
        script = tf.name
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        proc = subprocess.run(
            [sys.executable, script], env=env, cwd=REPO,
            capture_output=True, text=True, timeout=900)
    finally:
        os.unlink(script)
    if proc.returncode != 0:
        print(f"FAIL {os.path.relpath(path, REPO)}")
        print(proc.stdout[-2000:])
        print(proc.stderr[-4000:])
        return False
    return True


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="basename of a single doc file to check")
    args = ap.parse_args()

    files = doc_files(args.only)
    if not files:
        print(f"no doc file matches --only {args.only!r}")
        return 1
    failed = 0
    for path in files:
        blocks = python_blocks(path)
        rel = os.path.relpath(path, REPO)
        if not blocks:
            print(f"  ok {rel}: no python blocks")
            continue
        if run_file_blocks(path, blocks):
            print(f"  ok {rel}: {len(blocks)} block(s) executed")
        else:
            failed += 1
    if failed:
        print(f"{failed} doc file(s) have broken snippets")
        return 1
    print("all documentation snippets execute against the shipped API")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
