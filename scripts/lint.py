#!/usr/bin/env python
"""Run reprolint over the repository.

Usage:
    python scripts/lint.py                  # lint default targets vs baseline
    python scripts/lint.py --check-baseline # CI mode (also fails on stale)
    python scripts/lint.py --update-baseline
    python scripts/lint.py --list-rules
    python scripts/lint.py --list-env       # REPRO_* flag registry (markdown)

See docs/static-analysis.md for the rule catalogue and suppression syntax.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.lint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
