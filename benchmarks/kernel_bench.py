"""Kernel microbenchmarks.

CPU wall-times of interpret-mode Pallas are not TPU-predictive, so this
reports (a) wall time of the jitted *XLA emulation path* (the exact math
the kernel implements) and (b) the structural bytes-moved ratios that the
TPU kernel realizes (4.5 vs 16 bits/elem from HBM) — the quantity the
roofline memory term depends on."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.quant import decode_serving_weight, pack_serving_weight
from repro.core.m2xfp import quantize_act_m2xfp
from .common import csv_row, time_call


def run() -> dict:
    rng = np.random.default_rng(0)
    m, k, n = 512, 2048, 2048
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05)
    wp = pack_serving_weight(w)

    bf16_mm = jax.jit(lambda a, b: (a.astype(jnp.float32)
                                    @ b.astype(jnp.float32)))
    serve_mm = jax.jit(lambda a, p: a @ decode_serving_weight(p)
                       .astype(jnp.float32))
    quant = jax.jit(quantize_act_m2xfp)

    t_base = time_call(bf16_mm, x, w)
    t_serve = time_call(serve_mm, x, wp)
    t_quant = time_call(quant, x)

    packed_bytes = wp.codes.size + wp.scales.size + wp.meta.size
    ratio = (w.size * 2) / packed_bytes          # bf16 vs packed residency
    csv_row("kernel_dequant_matmul", t_serve,
            f"bf16_matmul_us={t_base:.1f};hbm_weight_bytes_ratio={ratio:.3f}"
            f";bits_per_elem={8 * packed_bytes / w.size:.2f}")
    csv_row("kernel_online_quantize", t_quant,
            f"tokens={m};features={k};bits_out=4.5")
    out = {"t_base": t_base, "t_serve": t_serve, "t_quant": t_quant,
           "ratio": ratio}
    from repro import obs
    if obs.enabled():
        g = obs.gauge("repro_kernel_bench_us",
                      "kernel microbenchmark wall time (microseconds)")
        for kind, t in (("bf16_matmul", t_base),
                        ("dequant_matmul", t_serve),
                        ("online_quantize", t_quant)):
            g.set(t, kernel=kind, m=m, k=k, n=n)
        obs.gauge("repro_kernel_bench_hbm_ratio",
                  "bf16 vs packed weight-stream residency ratio").set(ratio)
        obs.autodump()             # metrics.jsonl -> REPRO_OBS_DIR if set
    return out


if __name__ == "__main__":
    run()
