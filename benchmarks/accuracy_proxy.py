"""Paper Tbl. 2/3 proxy: model-level accuracy across hardware data formats.

No pretrained LLMs exist offline, so the reproduction target is the
*ordering and relative recovery*: train a small LM to convergence, then
evaluate held-out perplexity with weights AND activations fake-quantized
(W4A4) per format. Paper claims reproduced:

  * SMX4 catastrophic; MXFP4 degrades; NVFP4 better; M2XFP best
  * M2XFP recovers most of MXFP4's excess loss
    (paper: 70.63% of accuracy loss on LLMs; we report the ppl-gap
    recovery on the proxy model)
"""
from __future__ import annotations

from .common import csv_row, eval_ppl, time_call, trained_tiny_lm

FORMATS = ["fp4", "mxfp4", "nvfp4", "smx4", "m2xfp"]


def run(check: bool = True) -> dict:
    params, _ = trained_tiny_lm()
    out = {"fp16": eval_ppl(params, "none", "m2xfp")}
    for fmt in FORMATS:
        out[fmt] = eval_ppl(params, "qat", fmt)

    gap = {k: out[k] - out["fp16"] for k in FORMATS}
    recovery_vs_mxfp4 = 1.0 - gap["m2xfp"] / max(gap["mxfp4"], 1e-9)
    recovery_vs_nvfp4 = 1.0 - gap["m2xfp"] / max(gap["nvfp4"], 1e-9)
    if check:
        assert out["m2xfp"] < out["mxfp4"] < out["smx4"]
        assert out["m2xfp"] < out["nvfp4"] or \
            gap["m2xfp"] < 1.1 * gap["nvfp4"]
        assert recovery_vs_mxfp4 > 0.3, recovery_vs_mxfp4

    us = time_call(lambda: eval_ppl(params, "qat", "m2xfp"), iters=1,
                   warmup=0)
    csv_row("accuracy_proxy_tbl2_tbl3", us, ";".join(
        [f"ppl_{k}={v:.4f}" for k, v in out.items()]
        + [f"loss_recovery_vs_mxfp4={recovery_vs_mxfp4:.3f}",
           f"loss_recovery_vs_nvfp4={recovery_vs_nvfp4:.3f}"]))
    return out


if __name__ == "__main__":
    run()
