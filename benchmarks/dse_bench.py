"""Paper Figs. 6-7: encoding design-space exploration.

Sweeps metadata strategies x subgroup sizes on heavy-tailed LLM-like
tensors, reporting (EBW, MSE) points and checking the paper's Pareto
claims:
  Fig. 6 (fixed scale):  Elem-EM-top1 dominates at 4.5-4.75 EBW;
                         top-1 ~= top-2; Sg-EE never competitive.
  Fig. 7 (adaptive):     Sg-EM-2bit-adaptive overtakes Elem-EM.
"""
from __future__ import annotations

import numpy as np

from repro.core import STRATEGIES, mxfp4_reference, run_strategy
from .common import csv_row, heavy_tailed, mse, time_call

FIXED = ["elem_em_top1", "elem_em_top2", "elem_ee", "sg_em_1bit",
         "sg_em_2bit", "sg_ee_1bit", "sg_ee_2bit"]
ADAPTIVE = ["elem_em_top1_adaptive", "sg_em_2bit_adaptive",
            "sg_ee_2bit_adaptive"]
SUBGROUPS = [4, 8, 16, 32]


def run(check: bool = True) -> dict:
    rng = np.random.default_rng(42)
    x = heavy_tailed(rng, (512, 2048))
    base_dq, base_ebw = mxfp4_reference(x)
    results = {("mxfp4", 32): (base_ebw, mse(base_dq, x))}
    for name in FIXED + ADAPTIVE:
        for sg in SUBGROUPS:
            dq, ebw = run_strategy(name, x, subgroup=sg)
            results[(name, sg)] = (ebw, mse(dq, x))

    get = lambda n, sg: results[(n, sg)][1]
    derived = []
    if check:
        # Elem-EM dominates at EBW 4.5 under fixed scale (subgroup 8)
        assert get("elem_em_top1", 8) < get("sg_em_2bit", 8)
        assert get("elem_em_top1", 8) < get("sg_ee_2bit", 8)
        # top-1 ~= top-2 at its own EBW point
        assert abs(get("elem_em_top1", 8) - get("elem_em_top2", 8)) \
            < 0.35 * get("elem_em_top1", 8)
        # adaptive flips the ordering: Sg-EM-2bit-adaptive wins (Fig. 7)
        assert get("sg_em_2bit_adaptive", 8) < get("elem_em_top1_adaptive", 8)
        # overall ranking (paper 4.2.3)
        assert get("sg_em_2bit_adaptive", 8) < get("elem_em_top1_adaptive", 8) \
            <= get("elem_em_top1", 8) < get("sg_ee_2bit_adaptive", 8)
        derived.append("paper_fig6_fig7_orderings=confirmed")

    us = time_call(lambda: run_strategy("elem_em_top1", x, subgroup=8)[0])
    csv_row("dse_fig6_fig7", us, ";".join(
        [f"{n}@sg{sg}:ebw={results[(n, sg)][0]:.3f}:mse={results[(n, sg)][1]:.5f}"
         for (n, sg) in sorted(results) if sg in (8,)] + derived))
    return results


if __name__ == "__main__":
    run()
