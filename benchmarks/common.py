"""Shared benchmark utilities: calibrated tensor generators, the tiny-LM
trainer used by the accuracy-proxy benchmarks, timing, CSV output."""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts")


def heavy_tailed(rng, shape, df=4.0, ch_sigma=0.8):
    """LLM-like tensor: student-t entries + per-channel log-normal scales —
    matches the outlier structure that drives MX quantization error
    (paper Sec. 3.1)."""
    t = rng.standard_t(df=df, size=shape).astype(np.float32)
    ch = np.exp(ch_sigma * rng.standard_normal((1, shape[-1]))).astype(
        np.float32)
    return jnp.asarray(t * ch)


def act_like(rng, shape):
    """Activation-like: GELU-ish positively skewed with outlier channels."""
    g = rng.standard_normal(shape).astype(np.float32)
    out = np.where(g > 0, g, 0.05 * g)
    hot = rng.choice(shape[-1], max(1, shape[-1] // 100), replace=False)
    out[..., hot] *= 8.0
    return jnp.asarray(out)


def mse(a, b) -> float:
    return float(jnp.mean((a.astype(jnp.float32) - b.astype(jnp.float32))
                          ** 2))


def time_call(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
    from repro import obs
    if obs.enabled():
        # mirror into the registry so benchmark runs leave a
        # machine-readable metrics.jsonl next to the CSV stdout
        obs.gauge("repro_bench_us_per_call",
                  "benchmark wall time per call (microseconds)",
                  ).set(us_per_call, bench=name)
        for kv in derived.split(";"):
            k, _, v = kv.partition("=")
            try:
                val = float(v)
            except ValueError:
                continue                     # non-numeric derived field
            obs.gauge("repro_bench_derived",
                      "derived benchmark quantities from csv_row",
                      ).set(val, bench=name, field=k)


# ---------------------------------------------------------------------------
# Tiny LM for model-level accuracy benchmarks (Tbl. 2/3 proxy)
# ---------------------------------------------------------------------------

def tiny_cfg(quant="none", quant_format="m2xfp"):
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="tiny-llama", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=256, quant=quant,
        quant_format=quant_format, remat=False)


def _data_cfg():
    from repro.data.pipeline import DataConfig
    return DataConfig(batch=16, seq=128, vocab=256, seed=7, motif_len=12,
                      noise=0.05)


@functools.lru_cache(maxsize=1)
def trained_tiny_lm(steps: int = 300):
    """Train (or load cached) the tiny LM on the synthetic motif stream.
    Returns (params, eval_batches). Deterministic."""
    from repro.checkpoint import latest_step, restore_state, save_state
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params, loss_fn
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = tiny_cfg()
    data = SyntheticLM(_data_cfg())
    ckdir = os.path.join(ART_DIR, "tiny_lm")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, dtype=jnp.float32)

    if latest_step(ckdir) == steps:
        params, _ = restore_state(ckdir, params, steps)
    else:
        opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                              weight_decay=0.01)
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch):
            loss, g = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch))(params)
            params, opt, _ = adamw_update(params, g, opt, opt_cfg)
            return params, opt, loss

        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt, loss = step(params, opt, b)
        save_state(ckdir, steps, params)

    evals = [{k: jnp.asarray(v) for k, v in data.batch_at(10_000 + i).items()}
             for i in range(4)]
    return params, evals


def eval_ppl(params, quant: str, fmt: str) -> float:
    """Held-out perplexity of the tiny LM under W4A4 fake-quant ``fmt``."""
    import dataclasses
    from repro.models.model import loss_fn
    cfg = tiny_cfg(quant=quant, quant_format=fmt)
    _, evals = trained_tiny_lm()
    f = jax.jit(lambda p, b: loss_fn(p, cfg, b))
    losses = [float(f(params, b)) for b in evals]
    return float(np.exp(np.mean(losses)))
