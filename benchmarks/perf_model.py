"""Paper Fig. 13 / Tbl. 5 analogue: analytical accelerator performance and
energy model.

The paper's numbers come from a cycle-level simulator (DNNWeaver) + 28nm
synthesis; offline we reproduce the comparison with a transparent
first-order model of the same 32x32 systolic accelerator:

  compute cycles = sum over GEMMs of ceil(M/32) * ceil(N/32) * K
  dram cycles    = bytes(operands + outputs) / (BW per cycle)
  latency        = max(compute, dram)   (double-buffered overlap)
  energy         = MACs * e_mac(bits) + bytes * e_dram + decode/encode adders

Format models (from the paper's evaluation setup, Sec. 6.1/6.3):
  m2xfp        4-bit MACs, 4.5 bits/elem, +4.0% PE energy (Tbl. 5 area ratio)
  mxfp4        4-bit MACs, 4.25 bits/elem (accuracy not competitive)
  mx_ant       weights 4b, activations fall back to 8b (online type search
               impractical) -> 8b MACs on half the datapath
  mx_m_ant     like mx_ant + shift-add decode energy adder
  mx_olive     >=50% of tensors fall back to 8 bits (paper Sec. 6.3)
  microscopiq  4.4b weights (outlier blocks + >40b/block metadata),
               8.25b MXINT activations, ReCoN outlier-network energy adder

Workload: LLaMA2-7B decoder layer GEMMs at S=4096 (the paper's primary
eval model), batch 1.
"""
from __future__ import annotations

import dataclasses

from .common import csv_row, time_call

PE = 32                      # systolic array side
BW_BYTES_PER_CYCLE = 64      # HBM-ish: 32 GB/s @ 500 MHz
E_MAC4 = 1.0                 # energy units per 4-bit MAC
E_MAC8 = 2.2                 # per 8-bit MAC (superlinear in width)
E_DRAM_BYTE = 40.0           # DRAM access energy per byte (units)


@dataclasses.dataclass(frozen=True)
class FormatModel:
    name: str
    w_bits: float            # effective bits/elem resident (EBW)
    a_bits: float
    mac_energy: float        # per MAC
    pe_overhead: float = 0.0  # extra PE energy fraction (decode/meta logic)
    extra_energy_frac: float = 0.0  # e.g. MicroScopiQ ReCoN


FORMATS = [
    FormatModel("m2xfp", 4.5, 4.5, E_MAC4, pe_overhead=0.040),
    FormatModel("mxfp4", 4.25, 4.25, E_MAC4),
    FormatModel("mx_ant", 4.25, 8.25, 0.5 * (E_MAC4 + E_MAC8)),
    FormatModel("mx_m_ant", 4.25, 8.25, 0.5 * (E_MAC4 + E_MAC8),
                pe_overhead=0.06),
    FormatModel("mx_olive", 6.25, 6.25, 0.5 * (E_MAC4 + E_MAC8),
                extra_energy_frac=0.05),
    FormatModel("microscopiq", 4.4, 8.25, 0.5 * (E_MAC4 + E_MAC8),
                extra_energy_frac=0.12),
]


def llama7b_layer_gemms(seq: int = 4096):
    d, ff = 4096, 11008
    return [
        (seq, d, 3 * d),      # QKV
        (seq, d, d),          # O
        (seq, d, 2 * ff),     # gate+up
        (seq, ff, d),         # down
    ]


def evaluate(fmt: FormatModel, gemms) -> dict:
    compute = 0.0
    dram_bytes = 0.0
    macs = 0.0
    for m, k, n in gemms:
        compute += -(-m // PE) * -(-n // PE) * k
        macs += m * k * n
        dram_bytes += (m * k * fmt.a_bits + k * n * fmt.w_bits) / 8.0 \
            + m * n * 2.0                           # f16 outputs
    dram = dram_bytes / BW_BYTES_PER_CYCLE
    # 8-bit fallback halves effective MACs/cycle on that operand share
    slow = 2.0 if fmt.mac_energy > E_MAC4 else 1.0
    latency = max(compute * slow, dram)
    energy = macs * fmt.mac_energy * (1 + fmt.pe_overhead) \
        + dram_bytes * E_DRAM_BYTE
    energy *= 1 + fmt.extra_energy_frac
    return {"latency": latency, "energy": energy,
            "compute_cycles": compute * slow, "dram_cycles": dram}


def run(check: bool = True) -> dict:
    gemms = llama7b_layer_gemms()
    rows = {f.name: evaluate(f, gemms) for f in FORMATS}
    base = rows["m2xfp"]
    speedups = {k: v["latency"] / base["latency"] for k, v in rows.items()}
    energies = {k: v["energy"] / base["energy"] for k, v in rows.items()}
    if check:
        # M2XFP at least matches every accuracy-competitive baseline and
        # beats the 8-bit-fallback designs on both axes (paper Fig. 13)
        for k in ("mx_ant", "mx_m_ant", "mx_olive", "microscopiq"):
            assert speedups[k] >= 1.0, (k, speedups[k])
            assert energies[k] > 1.0, (k, energies[k])
    us = time_call(lambda: evaluate(FORMATS[0], gemms), iters=3, warmup=1)
    csv_row("perf_energy_fig13", us, ";".join(
        f"{k}:speedup_of_m2xfp={speedups[k]:.2f}x:energy_ratio={energies[k]:.2f}x"
        for k in rows))
    return {"speedups": speedups, "energies": energies}


if __name__ == "__main__":
    run()
