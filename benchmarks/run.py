"""Benchmark harness — one entry per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; assertion checks validate the
paper's claims (EXPERIMENTS.md records the outputs)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import ablations, accuracy_proxy, dse_bench, kernel_bench, \
        perf_model
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in [
        ("dse_fig6_fig7", dse_bench.run),
        ("accuracy_proxy_tbl2_tbl3", accuracy_proxy.run),
        ("m2_nvfp4_tbl6", ablations.run_m2_nvfp4),
        ("scale_rules_tbl8", ablations.run_scale_rules),
        ("bias_clamp_ablation", ablations.run_bias_clamp_ablation),
        ("perf_energy_fig13", perf_model.run),
        ("kernels", kernel_bench.run),
    ]:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},-1,FAILED:{type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
