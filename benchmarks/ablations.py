"""Paper Tbl. 6 (M2-NVFP4), Tbl. 8 (scale rules), and the bias-clamp
encoding ablation (Sec. 4.4: 'maximum deviation ... is only 0.02')."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    SCALE_RULES, quantize_act_m2nvfp4, quantize_act_m2xfp, quantize_mxfp4,
    quantize_nvfp4, quantize_weight_m2nvfp4, quantize_weight_m2xfp,
)
from repro.core.dtypes import FP6_E2M3, round_to_grid
from repro.core.m2xfp import elem_em_encode_parts
from repro.core.packing import group_reshape, group_unreshape
from repro.core.scaling import shared_scale_exponent
from repro.core.dtypes import exp2int
from .common import csv_row, eval_ppl, heavy_tailed, mse, time_call, \
    trained_tiny_lm


def run_m2_nvfp4(check: bool = True) -> dict:
    """Tbl. 6: Elem-EM/Sg-EM metadata also improves NVFP4 (at EBW 5.0)."""
    rng = np.random.default_rng(3)
    w = heavy_tailed(rng, (512, 2048))
    a = heavy_tailed(rng, (512, 2048), df=3.0)
    out = {
        "nvfp4_w": mse(quantize_nvfp4(w), w),
        "m2nvfp4_w": mse(quantize_weight_m2nvfp4(w), w),
        "nvfp4_a": mse(quantize_nvfp4(a), a),
        "m2nvfp4_a": mse(quantize_act_m2nvfp4(a), a),
    }
    if check:
        assert out["m2nvfp4_w"] < out["nvfp4_w"]
        assert out["m2nvfp4_a"] < out["nvfp4_a"]
    us = time_call(lambda: quantize_weight_m2nvfp4(w))
    csv_row("m2_nvfp4_tbl6", us,
            ";".join(f"{k}={v:.5f}" for k, v in out.items())
            + ";ebw_nvfp4=4.5;ebw_m2nvfp4=5.0")
    return out


def run_scale_rules(check: bool = True) -> dict:
    """Tbl. 8: M2XFP improves over MXFP4 under every shared-scale rule;
    ceil/rtne identical for FP4; model-level check on the tiny LM."""
    rng = np.random.default_rng(4)
    x = heavy_tailed(rng, (512, 2048))
    out = {}
    for rule in SCALE_RULES:
        base = mse(quantize_mxfp4(x, rule=rule), x)
        m2 = 0.5 * (mse(quantize_act_m2xfp(x, rule=rule), x)
                    + mse(quantize_weight_m2xfp(x, rule=rule), x))
        out[rule] = (base, m2)
        if check:
            assert m2 < base, rule
    if check:
        assert out["ceil"] == out["rtne"]        # paper: equivalent for FP4
    params, _ = trained_tiny_lm()
    ppl_floor = eval_ppl(params, "qat", "m2xfp")
    us = time_call(lambda: quantize_mxfp4(x, rule="ceil"))
    csv_row("scale_rules_tbl8", us, ";".join(
        f"{r}:mxfp4={b:.5f}:m2xfp={m:.5f}" for r, (b, m) in out.items())
        + f";tinylm_ppl_m2xfp_floor={ppl_floor:.4f}")
    return out


def run_bias_clamp_ablation(check: bool = True) -> dict:
    """Sec. 4.4: the -2-candidate drop of the bias-clamp encoding is
    negligible vs an ideal (unencodable) direct FP6 replacement."""
    rng = np.random.default_rng(5)
    x = heavy_tailed(rng, (512, 2048))
    xg = group_reshape(x.astype(jnp.float32), 32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    s = exp2int(shared_scale_exponent(amax, "floor"))

    # encoded (clamped) path
    q4, top1, v6, _, _ = elem_em_encode_parts(xg, s, 8)
    v6b = jnp.broadcast_to(v6[..., None], (*v6.shape, 8)).reshape(q4.shape)
    dq_enc = jnp.where(top1, v6b, q4) * s
    # ideal path: replace top-1 with its *unconstrained* FP6 value
    xs = xg / s
    q6_full = round_to_grid(xs, FP6_E2M3)
    dq_ideal = jnp.where(top1, q6_full, q4) * s

    m_enc = mse(group_unreshape(dq_enc), x)
    m_ideal = mse(group_unreshape(dq_ideal), x)
    rel = (m_enc - m_ideal) / max(m_ideal, 1e-12)

    # the paper's actual metric is MODEL-level: ppl deviation <= 0.02.
    params, _ = trained_tiny_lm()
    ppl_enc = eval_ppl(params, "qat", "m2xfp")
    ppl_ideal = eval_ppl(params, "qat", "m2xfp_ideal6")
    dppl = abs(ppl_enc - ppl_ideal)
    if check:
        # tensor MSE pays a small price for 2-bit alignment (mostly the
        # unreachable 7.5 code at the top bin); model-level it vanishes —
        # matching the paper's <=0.02 ppl claim
        assert rel < 0.15, rel
        assert dppl <= 0.03, dppl
    us = time_call(lambda: quantize_act_m2xfp(x))
    csv_row("bias_clamp_ablation", us,
            f"mse_encoded={m_enc:.6f};mse_ideal_fp6={m_ideal:.6f};"
            f"relative_excess={rel:.5f};ppl_encoded={ppl_enc:.4f};"
            f"ppl_ideal={ppl_ideal:.4f};ppl_delta={dppl:.4f}")
    return {"enc": m_enc, "ideal": m_ideal, "rel": rel, "dppl": dppl}


if __name__ == "__main__":
    run_m2_nvfp4()
    run_scale_rules()
    run_bias_clamp_ablation()
