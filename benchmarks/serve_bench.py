"""Serving benchmark: batched decode on packed MX-family weight streams.

Reports, for the continuous-batching engine (repro.serve) and every codec
named by ``--fmt`` (any packable ``repro.core.codecs`` entry — m2xfp,
mxfp4, nvfp4, ...), all on the SAME traffic trace:
  * measured tokens/sec of the CPU dry run (XLA mirror of the PE decode),
    split into prefill and decode phases, plus mean time-to-first-token in
    engine steps
  * chunked prefill vs the legacy one-token path: steps-to-first-token for
    the same traffic at both settings (the packed weight streams cross HBM
    once per chunk instead of once per prompt token)
  * HBM bytes/token of the packed deployment vs a bf16 deployment
  * the roofline-modeled decode throughput bound on TPU v5e
    (analysis/roofline.py) and the modeled packed-vs-bf16 speedup — the
    deploy-time claim of paper Sec. 6.5 (up to 1.91x on memory-bound
    decode), reproduced from the byte diet alone.

    PYTHONPATH=src python benchmarks/serve_bench.py --tokens 16
    PYTHONPATH=src python benchmarks/serve_bench.py \
        --fmt m2xfp mxfp4 nvfp4      # per-format tok/s on one trace

``--chaos`` switches to the fault-injection drill (docs/robustness.md):
the same traffic runs under a seeded fault plan — a bit-flip in one
slot's packed KV page, a NaN logit row, a transient launch failure and a
watchdog-tripping delay — and the run reports recovery metrics
(quarantines, retries, steps in DEGRADED) and FAILS (exit 1) if the
engine dies or nothing completes:

    PYTHONPATH=src python benchmarks/serve_bench.py --chaos --kv-quant
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.analysis.roofline import HBM_BW, roofline
from repro.core.codecs import get_codec, packed_codecs
from repro.models.config import ModelConfig
from repro.models.model import init_params
from repro.serve import ServeEngine, prequantize_params, tree_nbytes


def build_cfg(args, fmt: str) -> ModelConfig:
    return ModelConfig(
        name="serve-bench", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 32,
        n_kv_heads=args.d_model // 64, d_ff=3 * args.d_model,
        vocab_size=4096, remat=False, quant="serve", quant_format=fmt,
        kv_quant="m2xfp" if args.kv_quant else "none")


def decode_roofline(cfg, weight_bytes: int, kv_bytes: int, batch: int):
    """One decode step: every resident weight byte and every KV page byte
    crosses HBM once; FLOPs are 2·N per token (forward-only)."""
    step_bytes = weight_bytes + kv_bytes
    step_flops = 2.0 * cfg.active_params * batch
    terms = roofline(step_flops, step_bytes, 0.0, chips=1,
                     model_flops_=step_flops)
    tok_s = batch / max(terms.compute_s, terms.memory_s)
    return terms, tok_s, step_bytes / batch


def bench_format(fmt: str, args, params, prompts) -> dict:
    """Pack + serve one codec on the shared traffic trace; returns the
    per-format summary row."""
    cfg = build_cfg(args, fmt)
    packed = prequantize_params(params, cfg)

    dense_bytes = tree_nbytes(params)
    packed_bytes = tree_nbytes(packed)
    from repro.models.quant import PackedWeight
    gemm_packed = gemm_dense = 0
    for node in jax.tree.leaves(
            packed, is_leaf=lambda x: isinstance(x, PackedWeight)):
        if isinstance(node, PackedWeight):
            gemm_packed += tree_nbytes(node)
            # 2 elements per code byte; node.shape omits any stacked
            # per-layer leading dims, so count elements from the stream
            gemm_dense += 2 * (2 * node.codes.size)
    print(f"[{fmt}] weights: {dense_bytes / 2**20:.1f} MiB bf16 -> "
          f"{packed_bytes / 2**20:.1f} MiB packed; GEMM streams "
          f"{gemm_dense / 2**20:.1f} -> {gemm_packed / 2**20:.1f} MiB "
          f"({gemm_dense / gemm_packed:.2f}x, "
          f"{8 * gemm_packed / (gemm_dense / 2):.2f} bits/elem)")

    # -- measured: continuous-batching decode on this host ------------------
    eng = ServeEngine(packed, cfg, n_slots=args.slots, max_len=args.max_len,
                      prefill_chunk=args.prefill_chunk,
                      prefill_budget=args.prefill_budget)
    outs = eng.generate(prompts, max_new_tokens=args.tokens)
    sd = eng.stats.to_dict()       # fields + derived rates in one snapshot
    print(f"[{fmt}] served {args.requests} requests on {args.slots} slots: "
          f"{sd['generated_tokens']} new + {sd['prefill_tokens']} prompt "
          f"tokens in {sd['steps']} steps, {sd['wall_s']:.2f}s "
          f"({sd['tokens_per_sec']:.1f} tok/s measured on "
          f"{jax.default_backend()}, occupancy {sd['occupancy']:.2f})")
    print(f"[{fmt}] phases: {sd['prefill_steps']} prefill steps "
          f"({sd['prefill_tokens_per_sec']:.1f} prompt tok/s), "
          f"{sd['decode_steps']} decode steps "
          f"({sd['decode_tokens_per_sec']:.1f} new tok/s); "
          f"mean TTFT {eng.mean_ttft_steps():.1f} steps "
          f"(chunk={eng.chunk}, budget={args.prefill_budget})")
    assert all(len(o) == args.tokens for o in outs)

    # -- chunked prefill vs one-token path: steps to first token ------------
    one = ServeEngine(packed, cfg, n_slots=args.slots, max_len=args.max_len,
                      prefill_chunk=1)
    outs_one = one.generate(prompts, max_new_tokens=args.tokens)
    # codecs with a per-tensor activation scale (nvfp4) quantize each
    # launch's tokens against a shared amax, so chunked and one-token
    # prefill legitimately sample different tokens — parity is a property
    # of batch-invariant activation codecs only
    if get_codec(fmt).act_batch_invariant:
        assert outs_one == outs, "chunked prefill changed sampled tokens"
        parity = "identical tokens"
    else:
        parity = "per-tensor act scale: token parity not defined"
    ttft_c, ttft_1 = eng.mean_ttft_steps(), one.mean_ttft_steps()
    print(f"[{fmt}] steps-to-first-token: {ttft_1:.1f} one-token -> "
          f"{ttft_c:.1f} chunked ({ttft_1 / max(ttft_c, 1e-9):.1f}x fewer), "
          f"{parity}")

    # -- modeled: HBM bytes/token + v5e roofline bound ----------------------
    kv_packed = eng.kv_bytes()
    bf16_cfg = dataclasses.replace(cfg, quant="none", kv_quant="none")
    bf16_eng = ServeEngine(params, bf16_cfg, n_slots=args.slots,
                           max_len=args.max_len)
    kv_bf16 = bf16_eng.kv_bytes()

    t_p, tok_p, bpt_p = decode_roofline(cfg, packed_bytes, kv_packed,
                                        args.slots)
    t_d, tok_d, bpt_d = decode_roofline(cfg, dense_bytes, kv_bf16,
                                        args.slots)
    print(f"[{fmt}] HBM bytes/token: {bpt_p / 2**20:.2f} MiB packed vs "
          f"{bpt_d / 2**20:.2f} MiB bf16")
    print(f"[{fmt}] v5e roofline ({HBM_BW / 1e9:.0f} GB/s HBM): "
          f"{tok_p:,.0f} tok/s packed vs {tok_d:,.0f} tok/s bf16 "
          f"-> {tok_p / tok_d:.2f}x modeled speedup "
          f"(bound: {t_p.dominant})")

    return {
        "fmt": fmt,
        "stats": sd,
        "ttft_steps": {"chunked": ttft_c, "one_token": ttft_1},
        "bytes": {"weights_bf16": dense_bytes,
                  "weights_packed": packed_bytes,
                  "gemm_bits_per_elem": 8 * gemm_packed / (gemm_dense / 2),
                  "per_token_packed": bpt_p, "per_token_bf16": bpt_d},
        "roofline_tok_s": {"packed": tok_p, "bf16": tok_d},
    }


def bench_chaos(args, params, prompts) -> int:
    """Fault-injection drill: run the trace under a seeded fault plan and
    report recovery. Returns a process exit code (0 = engine survived and
    completed work, 1 = containment failed)."""
    from repro.serve import GuardConfig
    from repro.serve.guard import FAILED
    from repro.testing import FaultInjector, chaos_plan

    fmt = args.fmt[0]
    cfg = build_cfg(args, fmt)
    packed = prequantize_params(params, cfg)
    guard = GuardConfig(retry_backoff_s=0.01, seed=args.chaos_seed)
    eng = ServeEngine(packed, cfg, n_slots=args.slots, max_len=args.max_len,
                      prefill_chunk=args.prefill_chunk,
                      prefill_budget=args.prefill_budget, guard=guard,
                      max_queue=4 * args.slots, verify_weights=True,
                      source_params=params)

    # warm the jit caches BEFORE arming the watchdog or the faults: the
    # first launches include multi-second compilation, which would trip
    # any sane per-step budget
    eng.generate([prompts[0]], max_new_tokens=2)
    guard.watchdog_s = args.chaos_watchdog_s

    # faults land early in the run so short traces still see all of them
    plan = chaos_plan(args.chaos_seed, args.slots,
                      first_step=eng.stats.steps + 2,
                      horizon=max(8, args.tokens),
                      delay_s=2 * args.chaos_watchdog_s)
    print(f"[chaos:{fmt}] {plan.describe()}")
    reqs = [eng.submit(p, args.tokens) for p in prompts]
    with FaultInjector(eng, plan) as inj:
        eng.run()

    done = sum(1 for r in reqs if r.state == "finished")
    g = eng.guard_summary()
    print(f"[chaos:{fmt}] injected {len(inj.fired)} fault(s) "
          f"{sorted(inj.fired)}; {done}/{len(reqs)} requests completed, "
          f"{g['quarantines']} quarantined, {g['retries']} retries, "
          f"{g['watchdog_trips']} watchdog trips")
    print(f"[chaos:{fmt}] health={g['state']} "
          f"(degraded for {g['degraded_steps']} of {eng.stats.steps} "
          f"steps); shed={g['shed']} expired={g['expired']}")
    if g["state"] == FAILED:
        print(f"[chaos:{fmt}] FAIL: engine died ({g['fail_reason']})")
        return 1
    if done == 0:
        print(f"[chaos:{fmt}] FAIL: nothing completed under injection")
        return 1
    print(f"[chaos:{fmt}] PASS: faults contained, engine never FAILED")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", nargs="+", default=["m2xfp"],
                    choices=list(packed_codecs()), metavar="CODEC",
                    help="packed codec(s) to serve — every format runs the "
                         f"same traffic trace ({', '.join(packed_codecs())})")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--kv-quant", action="store_true",
                    help="store the KV cache in packed Sg-EM too")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="max prompt tokens per slot per step")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="cap on total prefill tokens per step")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable REPRO_OBS and drop metrics.jsonl / "
                         "trace.json / serve_stats.json under DIR "
                         "(docs/observability.md)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the seeded fault-injection drill instead of "
                         "the throughput bench (exit 1 if the engine fails "
                         "to contain the faults)")
    ap.add_argument("--chaos-seed", type=int, default=7,
                    help="fault-plan seed (same seed = same fault schedule)")
    ap.add_argument("--chaos-watchdog-s", type=float, default=5.0,
                    help="per-launch watchdog budget during --chaos")
    args = ap.parse_args()

    if args.obs_out:
        os.environ.setdefault("REPRO_OBS", "1")
        os.environ["REPRO_OBS_DIR"] = args.obs_out
    from repro import obs

    # one traffic trace, shared by every format (and by both prefill modes)
    rng = np.random.default_rng(5)
    lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1,
                        args.requests)
    prompts = [list(map(int, rng.integers(0, 4096, n))) for n in lens]
    params = init_params(jax.random.PRNGKey(0), build_cfg(args, "m2xfp"))

    if args.chaos:
        return bench_chaos(args, params, prompts)

    rows = [bench_format(fmt, args, params, prompts) for fmt in args.fmt]
    if len(rows) > 1:
        print("per-format throughput (same traffic trace):")
        for r in rows:
            print(f"  {r['fmt']:<12} {r['stats']['tokens_per_sec']:8.1f} "
                  f"tok/s measured, "
                  f"{r['roofline_tok_s']['packed']:12,.0f} tok/s v5e "
                  f"roofline, "
                  f"{r['bytes']['gemm_bits_per_elem']:.2f} bits/elem")

    if args.obs_out:
        os.makedirs(args.obs_out, exist_ok=True)
        snap = {
            "bench": "serve_bench",
            "backend": jax.default_backend(),
            "config": {k: getattr(args, k) for k in
                       ("fmt", "slots", "requests", "prompt_len", "tokens",
                        "d_model", "layers", "max_len", "kv_quant",
                        "prefill_chunk", "prefill_budget")},
            "formats": {r["fmt"]: {k: v for k, v in r.items() if k != "fmt"}
                        for r in rows},
        }
        path = os.path.join(args.obs_out, "serve_stats.json")
        with open(path, "w") as f:
            json.dump(snap, f, indent=2)
        obs.dump(args.obs_out)     # metrics.jsonl + trace.json alongside
        print(f"obs: wrote {path} (+ metrics.jsonl, trace.json)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
